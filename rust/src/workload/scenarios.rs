//! Scenario workload subsystem: declarative arrival-process construction.
//!
//! The paper evaluates InferLine on Gamma processes and two
//! AutoScale-derived traces; this module opens the workload dimension the
//! robustness harness (`experiments::robustness`) stresses — how the
//! Planner + Tuner closed loop behaves *under changes in the arrival
//! process* (flash crowds, diurnal cycles, bursty regime switching,
//! heavy-tailed inter-arrivals).
//!
//! Two layers:
//!
//! * **Generators** — deterministic, seed-parameterized arrival-process
//!   primitives: [`mmpp_trace`] (Markov-modulated Poisson regimes),
//!   [`diurnal_trace`] (sinusoidal rate curve), [`flash_crowd_trace`]
//!   (ramp / hold / decay spike), [`pareto_trace`] and
//!   [`lognormal_trace`] (heavy-tailed inter-arrivals), plus the generic
//!   [`rate_curve_trace`] they share, and file-backed replay with
//!   rescaling ([`Trace::load`] + [`rescale_time`] / [`rescale_to_rate`]).
//! * **Operators** — composition on traces: [`superpose`] (merge),
//!   [`splice`] (back-to-back), [`thin`] (Bernoulli subsampling) and
//!   [`ramp_between`] (probabilistic crossfade from one process into
//!   another).
//!
//! Both layers are reachable declaratively through a small JSON scenario
//! spec ([`ScenarioSpec`] / [`Scenario`]), loadable by the CLI
//! (`inferline trace scenario <spec.json>`). Every node derives its
//! sub-seeds deterministically from the spec seed ([`child_seed`]), so a
//! spec + seed pair is a bit-reproducible workload: same inputs, same
//! trace, byte for byte.
//!
//! ## JSON scenario-spec schema
//!
//! ```json
//! {
//!   "name": "flash-crowd-3x",
//!   "seed": 7,
//!   "scenario": {
//!     "kind": "flash_crowd",
//!     "base": 100, "peak": 300, "start": 60,
//!     "ramp": 5, "hold": 30, "decay": 30,
//!     "cv": 1.0, "duration": 240
//!   }
//! }
//! ```
//!
//! Node kinds (fields beyond `kind`):
//!
//! | kind           | fields                                                   |
//! |----------------|----------------------------------------------------------|
//! | `gamma`        | `lambda`, `cv`, `duration`                               |
//! | `mmpp`         | `rates` [..], `dwell` [..], `duration`                   |
//! | `diurnal`      | `base`, `amplitude`, `period`, `cv`?, `duration`         |
//! | `flash_crowd`  | `base`, `peak`, `start`, `ramp`, `hold`, `decay`, `cv`?, `duration` |
//! | `pareto`       | `lambda`, `shape` (α > 1), `duration`                    |
//! | `lognormal`    | `lambda`, `sigma`, `duration`                            |
//! | `replay`       | `path`, `time_scale`?, `target_rate`?                    |
//! | `superpose`    | `of` [nodes]                                             |
//! | `splice`       | `of` [nodes]                                             |
//! | `thin`         | `p`, `of` node                                           |
//! | `ramp_between` | `from` node, `to` node, `overlap`                        |

use std::path::Path;

use crate::util::json::Json;
use crate::util::rng::Rng;

use super::Trace;

/// Deterministically derive a sub-seed for the `tag`-th child of a
/// scenario node (splitmix64 finalizer over seed ⊕ tag). Independent
/// children get independent streams; the same (seed, tag) always yields
/// the same stream.
pub fn child_seed(seed: u64, tag: u64) -> u64 {
    let mut z = seed ^ tag.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Non-homogeneous Gamma process: the instantaneous rate is `rate(t)`
/// evaluated at the current arrival time (the same stepping
/// [`super::varying_trace`] uses), inter-arrival CV fixed at `cv`.
/// Rates are floored at a small positive value so a curve touching zero
/// cannot stall the generator.
pub fn rate_curve_trace(
    rate: impl Fn(f64) -> f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(cv > 0.0 && duration > 0.0);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        let lambda = rate(t).max(1e-3);
        t += rng.interarrival(lambda, cv);
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

/// Markov-modulated Poisson process: `rates[i]` is state i's Poisson
/// arrival rate, `dwell[i]` its mean sojourn (exponentially distributed).
/// The chain starts in state 0 and jumps uniformly among the *other*
/// states — with two states this is the classic bursty on/off regime
/// switcher. Burstiness shows up as inter-arrival CV > 1 whenever the
/// state rates are well separated.
pub fn mmpp_trace(rates: &[f64], dwell: &[f64], duration: f64, seed: u64) -> Trace {
    assert!(
        !rates.is_empty() && rates.len() == dwell.len(),
        "mmpp needs matching non-empty rates/dwell"
    );
    assert!(rates.iter().all(|&r| r > 0.0) && dwell.iter().all(|&d| d > 0.0));
    assert!(duration > 0.0);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut state = 0usize;
    let mut t = 0.0;
    while t < duration {
        let sojourn = rng.exp(1.0 / dwell[state]);
        let end = (t + sojourn).min(duration);
        let mut a = t;
        loop {
            a += rng.exp(rates[state]);
            if a >= end {
                break;
            }
            arrivals.push(a);
        }
        t = end;
        if rates.len() > 1 {
            let mut next = rng.usize(rates.len() - 1);
            if next >= state {
                next += 1;
            }
            state = next;
        }
    }
    Trace::new(arrivals)
}

/// Diurnal (sinusoidal) rate curve:
/// λ(t) = base · (1 + amplitude · sin(2πt / period)), Gamma(cv)
/// inter-arrivals. `amplitude` in [0, 1) keeps the rate positive.
pub fn diurnal_trace(
    base: f64,
    amplitude: f64,
    period: f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(base > 0.0 && (0.0..1.0).contains(&amplitude) && period > 0.0);
    let omega = 2.0 * std::f64::consts::PI / period;
    rate_curve_trace(
        |t| base * (1.0 + amplitude * (omega * t).sin()),
        cv,
        duration,
        seed,
    )
}

/// Flash crowd: baseline `base` QPS, then a spike at `start` that ramps
/// linearly to `peak` over `ramp` seconds, holds for `hold` seconds and
/// decays linearly back over `decay` seconds.
#[allow(clippy::too_many_arguments)]
pub fn flash_crowd_trace(
    base: f64,
    peak: f64,
    start: f64,
    ramp: f64,
    hold: f64,
    decay: f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(base > 0.0 && peak > 0.0 && start >= 0.0);
    assert!(ramp >= 0.0 && hold >= 0.0 && decay >= 0.0);
    rate_curve_trace(
        |t| {
            if t < start {
                base
            } else if t < start + ramp {
                base + (peak - base) * (t - start) / ramp
            } else if t < start + ramp + hold {
                peak
            } else if t < start + ramp + hold + decay {
                peak - (peak - base) * (t - start - ramp - hold) / decay
            } else {
                base
            }
        },
        cv,
        duration,
        seed,
    )
}

/// Renewal process with Pareto inter-arrivals: shape α > 1 (finite mean),
/// scale chosen so the mean rate is `lambda`. Small α (1 < α ≲ 2) gives
/// the heavy tail — rare but enormous gaps between dense packs of
/// arrivals.
pub fn pareto_trace(lambda: f64, shape: f64, duration: f64, seed: u64) -> Trace {
    assert!(lambda > 0.0 && shape > 1.0 && duration > 0.0);
    // E[X] = α·x_m / (α − 1) = 1/λ  ⇒  x_m = (α − 1) / (α·λ).
    let xm = (shape - 1.0) / (shape * lambda);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += xm / rng.f64_open().powf(1.0 / shape);
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

/// Renewal process with lognormal inter-arrivals: log-σ `sigma`, log-μ
/// chosen so the mean rate is `lambda` (μ = −ln λ − σ²/2). σ ≳ 1.5 gives
/// inter-arrival CVs well above the Gamma traces the paper studies.
pub fn lognormal_trace(lambda: f64, sigma: f64, duration: f64, seed: u64) -> Trace {
    assert!(lambda > 0.0 && sigma > 0.0 && duration > 0.0);
    let mu = -lambda.ln() - sigma * sigma / 2.0;
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += (mu + sigma * rng.normal()).exp();
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

// ---------------------------------------------------------------------------
// Operators
// ---------------------------------------------------------------------------

/// Superpose (merge) several traces into one arrival stream.
pub fn superpose(traces: &[Trace]) -> Trace {
    Trace::from_unsorted(
        traces.iter().flat_map(|t| t.arrivals.iter().copied()).collect(),
    )
}

/// Splice traces back-to-back: each subsequent trace is shifted to start
/// where the previous one ended.
pub fn splice(traces: &[Trace]) -> Trace {
    traces.iter().fold(Trace::default(), |acc, t| acc.concat(t))
}

/// Bernoulli thinning: keep each arrival independently with probability
/// `p` (models subsampled or partially migrated traffic).
pub fn thin(trace: &Trace, p: f64, seed: u64) -> Trace {
    assert!((0.0..=1.0).contains(&p), "thin probability {p}");
    let mut rng = Rng::new(seed);
    Trace::new(trace.arrivals.iter().copied().filter(|_| rng.bool(p)).collect())
}

/// Probabilistic crossfade: play `a` in full, then hand traffic over to
/// `b` across the trailing `overlap` seconds of `a` — inside the window
/// each `a`-arrival survives with the fraction of the window remaining
/// and each `b`-arrival with the fraction elapsed, so the mix shifts
/// linearly from pure `a` to pure `b`. `b` is rebased to start at the
/// beginning of the window and continues after `a` ends.
pub fn ramp_between(a: &Trace, b: &Trace, overlap: f64, seed: u64) -> Trace {
    assert!(overlap >= 0.0);
    let a_end = a.arrivals.last().copied().unwrap_or(0.0);
    let t0 = (a_end - overlap).max(0.0);
    let window = (a_end - t0).max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::with_capacity(a.len() + b.len());
    for &t in &a.arrivals {
        let fade = ((t - t0) / window).clamp(0.0, 1.0);
        if fade <= 0.0 || rng.bool(1.0 - fade) {
            arrivals.push(t);
        }
    }
    for &t in &b.arrivals {
        let shifted = t0 + t;
        let fade = ((shifted - t0) / window).clamp(0.0, 1.0);
        if fade >= 1.0 || rng.bool(fade) {
            arrivals.push(shifted);
        }
    }
    Trace::from_unsorted(arrivals)
}

/// Rescale time by `factor` (> 1 stretches the trace and divides the
/// rate; < 1 compresses it and multiplies the rate).
pub fn rescale_time(trace: &Trace, factor: f64) -> Trace {
    assert!(factor > 0.0);
    Trace::new(trace.arrivals.iter().map(|&t| t * factor).collect())
}

/// Rescale time so the trace's mean rate becomes `target_qps`.
pub fn rescale_to_rate(trace: &Trace, target_qps: f64) -> Trace {
    assert!(target_qps > 0.0);
    let rate = trace.mean_rate();
    if rate <= 0.0 {
        return trace.clone();
    }
    rescale_time(trace, rate / target_qps)
}

// ---------------------------------------------------------------------------
// Declarative scenario tree
// ---------------------------------------------------------------------------

/// A declarative scenario node: a generator leaf or a composition
/// operator over sub-scenarios. Built from JSON by [`Scenario::parse`]
/// and realized into a [`Trace`] by [`Scenario::build`].
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    Gamma { lambda: f64, cv: f64, duration: f64 },
    Mmpp { rates: Vec<f64>, dwell: Vec<f64>, duration: f64 },
    Diurnal { base: f64, amplitude: f64, period: f64, cv: f64, duration: f64 },
    FlashCrowd {
        base: f64,
        peak: f64,
        start: f64,
        ramp: f64,
        hold: f64,
        decay: f64,
        cv: f64,
        duration: f64,
    },
    Pareto { lambda: f64, shape: f64, duration: f64 },
    Lognormal { lambda: f64, sigma: f64, duration: f64 },
    Replay { path: String, time_scale: f64, target_rate: Option<f64> },
    Superpose(Vec<Scenario>),
    Splice(Vec<Scenario>),
    Thin { p: f64, of: Box<Scenario> },
    RampBetween { from: Box<Scenario>, to: Box<Scenario>, overlap: f64 },
}

fn req_num(node: &Json, key: &str) -> Result<f64, String> {
    node.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("scenario node missing numeric field {key:?}"))
}

/// Range check performed at parse time, so a malformed-but-numeric spec
/// surfaces as a CLI error instead of tripping a generator assertion.
fn check(cond: bool, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(format!("scenario field out of range: {what}"))
    }
}

fn opt_num(node: &Json, key: &str, default: f64) -> Result<f64, String> {
    match node.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("scenario field {key:?} must be a number")),
    }
}

fn num_array(node: &Json, key: &str) -> Result<Vec<f64>, String> {
    let arr = node
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("scenario node missing array field {key:?}"))?;
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| format!("{key:?} must contain numbers")))
        .collect()
}

fn node_list(node: &Json, key: &str) -> Result<Vec<Scenario>, String> {
    let arr = node
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("scenario node missing array field {key:?}"))?;
    if arr.is_empty() {
        return Err(format!("scenario field {key:?} must not be empty"));
    }
    arr.iter().map(Scenario::parse).collect()
}

fn sub_node(node: &Json, key: &str) -> Result<Box<Scenario>, String> {
    let sub = node
        .get(key)
        .ok_or_else(|| format!("scenario node missing field {key:?}"))?;
    Ok(Box::new(Scenario::parse(sub)?))
}

impl Scenario {
    /// Parse one scenario node from its JSON form (see the module docs
    /// for the schema).
    pub fn parse(node: &Json) -> Result<Scenario, String> {
        let kind = node
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("scenario node missing string field \"kind\"")?;
        match kind {
            "gamma" => {
                let (lambda, cv) = (req_num(node, "lambda")?, opt_num(node, "cv", 1.0)?);
                let duration = req_num(node, "duration")?;
                check(lambda > 0.0, "gamma lambda must be > 0")?;
                check(cv > 0.0, "gamma cv must be > 0")?;
                check(duration > 0.0, "gamma duration must be > 0")?;
                Ok(Scenario::Gamma { lambda, cv, duration })
            }
            "mmpp" => {
                let rates = num_array(node, "rates")?;
                let dwell = num_array(node, "dwell")?;
                if rates.is_empty() || rates.len() != dwell.len() {
                    return Err("mmpp needs matching non-empty \"rates\" and \"dwell\"".into());
                }
                let duration = req_num(node, "duration")?;
                check(rates.iter().all(|&r| r > 0.0), "mmpp rates must be > 0")?;
                check(dwell.iter().all(|&d| d > 0.0), "mmpp dwell must be > 0")?;
                check(duration > 0.0, "mmpp duration must be > 0")?;
                Ok(Scenario::Mmpp { rates, dwell, duration })
            }
            "diurnal" => {
                let (base, amplitude) = (req_num(node, "base")?, req_num(node, "amplitude")?);
                let (period, cv) = (req_num(node, "period")?, opt_num(node, "cv", 1.0)?);
                let duration = req_num(node, "duration")?;
                check(base > 0.0, "diurnal base must be > 0")?;
                check((0.0..1.0).contains(&amplitude), "diurnal amplitude must be in [0, 1)")?;
                check(period > 0.0 && cv > 0.0, "diurnal period and cv must be > 0")?;
                check(duration > 0.0, "diurnal duration must be > 0")?;
                Ok(Scenario::Diurnal { base, amplitude, period, cv, duration })
            }
            "flash_crowd" => {
                let (base, peak) = (req_num(node, "base")?, req_num(node, "peak")?);
                let (start, ramp) = (req_num(node, "start")?, opt_num(node, "ramp", 1.0)?);
                let (hold, decay) = (req_num(node, "hold")?, opt_num(node, "decay", 1.0)?);
                let (cv, duration) = (opt_num(node, "cv", 1.0)?, req_num(node, "duration")?);
                check(base > 0.0 && peak > 0.0, "flash_crowd rates must be > 0")?;
                check(
                    start >= 0.0 && ramp >= 0.0 && hold >= 0.0 && decay >= 0.0,
                    "flash_crowd phases must be >= 0",
                )?;
                check(cv > 0.0 && duration > 0.0, "flash_crowd cv and duration must be > 0")?;
                Ok(Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration })
            }
            "pareto" => {
                let (lambda, shape) = (req_num(node, "lambda")?, req_num(node, "shape")?);
                let duration = req_num(node, "duration")?;
                check(lambda > 0.0, "pareto lambda must be > 0")?;
                check(shape > 1.0, "pareto shape must be > 1 (finite mean)")?;
                check(duration > 0.0, "pareto duration must be > 0")?;
                Ok(Scenario::Pareto { lambda, shape, duration })
            }
            "lognormal" => {
                let (lambda, sigma) = (req_num(node, "lambda")?, req_num(node, "sigma")?);
                let duration = req_num(node, "duration")?;
                check(lambda > 0.0 && sigma > 0.0, "lognormal lambda and sigma must be > 0")?;
                check(duration > 0.0, "lognormal duration must be > 0")?;
                Ok(Scenario::Lognormal { lambda, sigma, duration })
            }
            "replay" => {
                let path = node
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or("replay node missing string field \"path\"")?
                    .to_string();
                let target_rate = match node.get("target_rate") {
                    None => None,
                    Some(v) => Some(
                        v.as_f64().ok_or("\"target_rate\" must be a number")?,
                    ),
                };
                let time_scale = opt_num(node, "time_scale", 1.0)?;
                check(time_scale > 0.0, "replay time_scale must be > 0")?;
                check(
                    target_rate.map_or(true, |r| r > 0.0),
                    "replay target_rate must be > 0",
                )?;
                Ok(Scenario::Replay { path, time_scale, target_rate })
            }
            "superpose" => Ok(Scenario::Superpose(node_list(node, "of")?)),
            "splice" => Ok(Scenario::Splice(node_list(node, "of")?)),
            "thin" => {
                let p = req_num(node, "p")?;
                check((0.0..=1.0).contains(&p), "thin p must be in [0, 1]")?;
                Ok(Scenario::Thin { p, of: sub_node(node, "of")? })
            }
            "ramp_between" => {
                let overlap = req_num(node, "overlap")?;
                check(overlap >= 0.0, "ramp_between overlap must be >= 0")?;
                Ok(Scenario::RampBetween {
                    from: sub_node(node, "from")?,
                    to: sub_node(node, "to")?,
                    overlap,
                })
            }
            other => Err(format!("unknown scenario kind {other:?}")),
        }
    }

    /// Realize the scenario into an arrival trace. Deterministic in
    /// (self, seed): every child derives its sub-seed via [`child_seed`],
    /// so sibling subtrees have independent but reproducible streams.
    pub fn build(&self, seed: u64) -> Result<Trace, String> {
        match self {
            Scenario::Gamma { lambda, cv, duration } => {
                Ok(super::gamma_trace(*lambda, *cv, *duration, seed))
            }
            Scenario::Mmpp { rates, dwell, duration } => {
                Ok(mmpp_trace(rates, dwell, *duration, seed))
            }
            Scenario::Diurnal { base, amplitude, period, cv, duration } => {
                Ok(diurnal_trace(*base, *amplitude, *period, *cv, *duration, seed))
            }
            Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration } => {
                Ok(flash_crowd_trace(
                    *base, *peak, *start, *ramp, *hold, *decay, *cv, *duration, seed,
                ))
            }
            Scenario::Pareto { lambda, shape, duration } => {
                Ok(pareto_trace(*lambda, *shape, *duration, seed))
            }
            Scenario::Lognormal { lambda, sigma, duration } => {
                Ok(lognormal_trace(*lambda, *sigma, *duration, seed))
            }
            Scenario::Replay { path, time_scale, target_rate } => {
                let mut trace = Trace::load(Path::new(path))?;
                if (*time_scale - 1.0).abs() > 1e-12 {
                    trace = rescale_time(&trace, *time_scale);
                }
                if let Some(target) = target_rate {
                    trace = rescale_to_rate(&trace, *target);
                }
                Ok(trace)
            }
            Scenario::Superpose(parts) => {
                let traces = parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.build(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(superpose(&traces))
            }
            Scenario::Splice(parts) => {
                let traces = parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.build(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(splice(&traces))
            }
            Scenario::Thin { p, of } => {
                let inner = of.build(child_seed(seed, 0))?;
                Ok(thin(&inner, *p, child_seed(seed, 1)))
            }
            Scenario::RampBetween { from, to, overlap } => {
                let a = from.build(child_seed(seed, 0))?;
                let b = to.build(child_seed(seed, 1))?;
                Ok(ramp_between(&a, &b, *overlap, child_seed(seed, 2)))
            }
        }
    }
}

/// A named, seeded scenario document: the on-disk unit the CLI loads.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    pub scenario: Scenario,
}

impl ScenarioSpec {
    /// Parse a full spec document (`{"name", "seed", "scenario"}`; name
    /// defaults to `"scenario"`, seed to 42).
    pub fn parse(doc: &Json) -> Result<ScenarioSpec, String> {
        let scenario = doc
            .get("scenario")
            .ok_or("spec missing field \"scenario\"")?;
        Ok(ScenarioSpec {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("scenario")
                .to_string(),
            seed: doc.get("seed").and_then(Json::as_f64).unwrap_or(42.0) as u64,
            scenario: Scenario::parse(scenario)?,
        })
    }

    pub fn parse_str(text: &str) -> Result<ScenarioSpec, String> {
        Self::parse(&Json::parse(text)?)
    }

    pub fn load(path: &Path) -> Result<ScenarioSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_str(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Build the trace with the spec's own seed.
    pub fn build(&self) -> Result<Trace, String> {
        self.scenario.build(self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gamma_trace;

    fn window_rate(tr: &Trace, lo: f64, hi: f64) -> f64 {
        let n = tr.arrivals.iter().filter(|&&t| t >= lo && t < hi).count();
        n as f64 / (hi - lo)
    }

    #[test]
    fn child_seed_is_stable_and_spreads() {
        assert_eq!(child_seed(7, 0), child_seed(7, 0));
        assert_ne!(child_seed(7, 0), child_seed(7, 1));
        assert_ne!(child_seed(7, 0), child_seed(8, 0));
    }

    #[test]
    fn mmpp_is_deterministic_and_bursty() {
        let rates = [20.0, 300.0];
        let dwell = [15.0, 15.0];
        let a = mmpp_trace(&rates, &dwell, 300.0, 3);
        let b = mmpp_trace(&rates, &dwell, 300.0, 3);
        assert_eq!(a, b);
        assert_ne!(a, mmpp_trace(&rates, &dwell, 300.0, 4));
        // Mean rate between the state rates; CV well above Poisson.
        assert!(a.mean_rate() > 30.0 && a.mean_rate() < 290.0, "rate {}", a.mean_rate());
        assert!(a.cv() > 1.1, "cv {}", a.cv());
    }

    #[test]
    fn diurnal_peaks_and_troughs() {
        let tr = diurnal_trace(100.0, 0.5, 120.0, 1.0, 240.0, 5);
        // sin peaks at t=30 (+mod period), troughs at t=90.
        let peak = window_rate(&tr, 15.0, 45.0) + window_rate(&tr, 135.0, 165.0);
        let trough = window_rate(&tr, 75.0, 105.0) + window_rate(&tr, 195.0, 225.0);
        assert!(peak > 1.5 * trough, "peak {peak} vs trough {trough}");
        assert_eq!(tr, diurnal_trace(100.0, 0.5, 120.0, 1.0, 240.0, 5));
    }

    #[test]
    fn flash_crowd_hits_peak_then_recovers() {
        let tr = flash_crowd_trace(100.0, 400.0, 60.0, 5.0, 30.0, 15.0, 1.0, 180.0, 7);
        let before = window_rate(&tr, 10.0, 55.0);
        let during = window_rate(&tr, 66.0, 94.0);
        let after = window_rate(&tr, 130.0, 175.0);
        assert!((before - 100.0).abs() < 25.0, "before {before}");
        assert!((during - 400.0).abs() < 80.0, "during {during}");
        assert!((after - 100.0).abs() < 25.0, "after {after}");
    }

    #[test]
    fn pareto_has_heavy_tail() {
        let tr = pareto_trace(100.0, 1.6, 120.0, 9);
        assert!(tr.mean_rate() > 40.0 && tr.mean_rate() < 200.0, "rate {}", tr.mean_rate());
        // Tail heaviness: the p99 inter-arrival dwarfs the median
        // (theoretical ratio 50^(1/1.6) ≈ 11.5 for Pareto).
        let mut gaps: Vec<f64> = tr.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_by(f64::total_cmp);
        let median = gaps[gaps.len() / 2];
        let p99 = gaps[gaps.len() * 99 / 100];
        assert!(p99 > 5.0 * median, "p99 {p99} vs median {median}");
    }

    #[test]
    fn lognormal_matches_rate_with_high_cv() {
        let tr = lognormal_trace(100.0, 1.5, 120.0, 11);
        assert!((tr.mean_rate() - 100.0).abs() < 25.0, "rate {}", tr.mean_rate());
        assert!(tr.cv() > 1.3, "cv {}", tr.cv());
    }

    #[test]
    fn superpose_adds_rates_and_sorts() {
        let a = gamma_trace(50.0, 1.0, 60.0, 1);
        let b = gamma_trace(50.0, 1.0, 60.0, 2);
        let merged = superpose(&[a.clone(), b.clone()]);
        assert_eq!(merged.len(), a.len() + b.len());
        assert!(merged.arrivals.windows(2).all(|w| w[0] <= w[1]));
        assert!((merged.mean_rate() - 100.0).abs() < 15.0, "rate {}", merged.mean_rate());
    }

    #[test]
    fn thin_keeps_expected_fraction() {
        let tr = gamma_trace(100.0, 1.0, 60.0, 13);
        let half = thin(&tr, 0.5, 17);
        let frac = half.len() as f64 / tr.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "kept {frac}");
        assert_eq!(half, thin(&tr, 0.5, 17));
        assert_eq!(thin(&tr, 1.0, 1).len(), tr.len());
        assert_eq!(thin(&tr, 0.0, 1).len(), 0);
    }

    #[test]
    fn splice_concatenates_durations() {
        let a = gamma_trace(80.0, 1.0, 30.0, 19);
        let b = gamma_trace(20.0, 1.0, 30.0, 23);
        let joined = splice(&[a.clone(), b.clone()]);
        assert_eq!(joined.len(), a.len() + b.len());
        assert!(joined.arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn ramp_between_crossfades() {
        let a = gamma_trace(200.0, 1.0, 60.0, 29);
        let b = gamma_trace(50.0, 1.0, 60.0, 31);
        let tr = ramp_between(&a, &b, 20.0, 37);
        assert!(tr.arrivals.windows(2).all(|w| w[0] <= w[1]));
        let early = window_rate(&tr, 0.0, 35.0);
        let late = window_rate(&tr, 65.0, 95.0);
        assert!(early > 2.0 * late, "early {early} late {late}");
    }

    #[test]
    fn rescale_changes_rate() {
        let tr = gamma_trace(100.0, 1.0, 60.0, 41);
        let double = rescale_time(&tr, 0.5);
        assert!((double.mean_rate() - 2.0 * tr.mean_rate()).abs() < 10.0);
        let target = rescale_to_rate(&tr, 40.0);
        assert!((target.mean_rate() - 40.0).abs() < 2.0, "rate {}", target.mean_rate());
    }

    #[test]
    fn spec_parses_and_builds_deterministically() {
        let text = r#"{
            "name": "composite",
            "seed": 9,
            "scenario": {
                "kind": "superpose",
                "of": [
                    {"kind": "gamma", "lambda": 60, "cv": 1.0, "duration": 60},
                    {"kind": "thin", "p": 0.5,
                     "of": {"kind": "mmpp", "rates": [30, 120], "dwell": [10, 10],
                            "duration": 60}}
                ]
            }
        }"#;
        let spec = ScenarioSpec::parse_str(text).unwrap();
        assert_eq!(spec.name, "composite");
        assert_eq!(spec.seed, 9);
        let a = spec.build().unwrap();
        let b = spec.build().unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // A different seed changes the realization.
        assert_ne!(a, spec.scenario.build(10).unwrap());
    }

    #[test]
    fn spec_parse_rejects_malformed_nodes() {
        for text in [
            r#"{"scenario": {"kind": "nope"}}"#,
            r#"{"scenario": {"kind": "gamma", "cv": 1.0}}"#,
            r#"{"scenario": {"kind": "mmpp", "rates": [1], "dwell": [], "duration": 10}}"#,
            r#"{"scenario": {"kind": "thin", "p": 0.5}}"#,
            r#"{"name": "no-scenario"}"#,
            // Numeric but out of range: must error at parse, not panic in
            // a generator assertion at build time.
            r#"{"scenario": {"kind": "gamma", "lambda": 0, "duration": 10}}"#,
            r#"{"scenario": {"kind": "mmpp", "rates": [0, 5], "dwell": [1, 1], "duration": 10}}"#,
            r#"{"scenario": {"kind": "diurnal", "base": 50, "amplitude": 1.5, "period": 60,
                "duration": 60}}"#,
            r#"{"scenario": {"kind": "pareto", "lambda": 50, "shape": 0.9, "duration": 10}}"#,
            r#"{"scenario": {"kind": "thin", "p": 1.5,
                "of": {"kind": "gamma", "lambda": 10, "duration": 5}}}"#,
        ] {
            assert!(ScenarioSpec::parse_str(text).is_err(), "{text}");
        }
    }

    #[test]
    fn replay_node_rescales_a_saved_trace() {
        let dir = std::env::temp_dir().join("inferline-scenario-replay");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("replay.txt");
        gamma_trace(50.0, 1.0, 30.0, 43).save(&path).unwrap();
        let spec = ScenarioSpec::parse_str(&format!(
            r#"{{"scenario": {{"kind": "replay", "path": {:?}, "target_rate": 100}}}}"#,
            path.to_str().unwrap()
        ))
        .unwrap();
        let tr = spec.build().unwrap();
        assert!((tr.mean_rate() - 100.0).abs() < 5.0, "rate {}", tr.mean_rate());
    }
}
