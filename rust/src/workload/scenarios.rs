//! Scenario workload subsystem: declarative arrival-process construction.
//!
//! The paper evaluates InferLine on Gamma processes and two
//! AutoScale-derived traces; this module opens the workload dimension the
//! robustness harness (`experiments::robustness`) stresses — how the
//! Planner + Tuner closed loop behaves *under changes in the arrival
//! process* (flash crowds, diurnal cycles, bursty regime switching,
//! heavy-tailed inter-arrivals).
//!
//! Two layers:
//!
//! * **Generators** — deterministic, seed-parameterized arrival-process
//!   primitives: [`mmpp_trace`] (Markov-modulated Poisson regimes),
//!   [`diurnal_trace`] (sinusoidal rate curve), [`flash_crowd_trace`]
//!   (ramp / hold / decay spike), [`pareto_trace`] and
//!   [`lognormal_trace`] (heavy-tailed inter-arrivals), plus the generic
//!   [`rate_curve_trace`] they share, and file-backed replay with
//!   rescaling ([`Trace::load`] + [`rescale_time`] / [`rescale_to_rate`]).
//! * **Operators** — composition on traces: [`superpose`] (merge),
//!   [`splice`] (back-to-back), [`thin`] (Bernoulli subsampling) and
//!   [`ramp_between`] (probabilistic crossfade from one process into
//!   another).
//!
//! Both layers are reachable declaratively through a small JSON scenario
//! spec ([`ScenarioSpec`] / [`Scenario`]), loadable by the CLI
//! (`inferline trace scenario <spec.json>`). Every node derives its
//! sub-seeds deterministically from the spec seed ([`child_seed`]), so a
//! spec + seed pair is a bit-reproducible workload: same inputs, same
//! trace, byte for byte.
//!
//! ## JSON scenario-spec schema
//!
//! ```json
//! {
//!   "name": "flash-crowd-3x",
//!   "seed": 7,
//!   "scenario": {
//!     "kind": "flash_crowd",
//!     "base": 100, "peak": 300, "start": 60,
//!     "ramp": 5, "hold": 30, "decay": 30,
//!     "cv": 1.0, "duration": 240
//!   }
//! }
//! ```
//!
//! Node kinds (fields beyond `kind`):
//!
//! | kind           | fields                                                   |
//! |----------------|----------------------------------------------------------|
//! | `gamma`        | `lambda`, `cv`, `duration`                               |
//! | `mmpp`         | `rates` [..], `dwell` [..], `duration`                   |
//! | `diurnal`      | `base`, `amplitude`, `period`, `cv`?, `duration`         |
//! | `flash_crowd`  | `base`, `peak`, `start`, `ramp`, `hold`, `decay`, `cv`?, `duration` |
//! | `pareto`       | `lambda`, `shape` (α > 1), `duration`                    |
//! | `lognormal`    | `lambda`, `sigma`, `duration`                            |
//! | `replay`       | `path`, `time_scale`? ⊕ `target_rate`?                   |
//! | `autoscale`    | `workload` (big_spike\|instant_spike), `max_qps`, `time_scale`? ⊕ `target_rate`? |
//! | `production`   | `path` (`builtin:…` or per-minute CSV), `cv`?, `max_qps`?, `limit_minutes`? |
//! | `superpose`    | `of` [nodes]                                             |
//! | `splice`       | `of` [nodes]                                             |
//! | `thin`         | `p`, `of` node                                           |
//! | `ramp_between` | `from` node, `to` node, `overlap`                        |
//!
//! `time_scale` and `target_rate` are mutually exclusive (⊕):
//! `target_rate` renormalizes the mean rate after any time scaling, so
//! combining them would erase the `time_scale` exactly and silently.
//!
//! A spec may also carry an optional top-level `"quick"` node — an
//! alternative scenario served in quick (CI) mode when plain duration
//! scaling ([`Scenario::scaled`]) does not fit, e.g. replayed timelines
//! whose horizon is fixed by the source trace.
//!
//! Parse errors name the offending node by its path from the document
//! root (`scenario.of[1]: mmpp dwell must be > 0`), so a malformed
//! checked-in spec is actionable from the CLI error alone.

use std::path::Path;

use crate::util::json::{opt_f64_at, req_f64_at as req_num, Json};
use crate::util::rng::Rng;

use super::Trace;

/// Deterministically derive a sub-seed for the `tag`-th child of a
/// scenario node (splitmix64 finalizer over seed ⊕ tag). Independent
/// children get independent streams; the same (seed, tag) always yields
/// the same stream.
pub fn child_seed(seed: u64, tag: u64) -> u64 {
    let mut z = seed ^ tag.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------------

/// Non-homogeneous Gamma process: the instantaneous rate is `rate(t)`
/// evaluated at the current arrival time (the same stepping
/// [`super::varying_trace`] uses), inter-arrival CV fixed at `cv`.
/// Rates are floored at a small positive value so a curve touching zero
/// cannot stall the generator.
pub fn rate_curve_trace(
    rate: impl Fn(f64) -> f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(cv > 0.0 && duration > 0.0);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        let lambda = rate(t).max(1e-3);
        t += rng.interarrival(lambda, cv);
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

/// Markov-modulated Poisson process: `rates[i]` is state i's Poisson
/// arrival rate, `dwell[i]` its mean sojourn (exponentially distributed).
/// The chain starts in state 0 and jumps uniformly among the *other*
/// states — with two states this is the classic bursty on/off regime
/// switcher. Burstiness shows up as inter-arrival CV > 1 whenever the
/// state rates are well separated.
pub fn mmpp_trace(rates: &[f64], dwell: &[f64], duration: f64, seed: u64) -> Trace {
    assert!(
        !rates.is_empty() && rates.len() == dwell.len(),
        "mmpp needs matching non-empty rates/dwell"
    );
    assert!(rates.iter().all(|&r| r > 0.0) && dwell.iter().all(|&d| d > 0.0));
    assert!(duration > 0.0);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut state = 0usize;
    let mut t = 0.0;
    while t < duration {
        let sojourn = rng.exp(1.0 / dwell[state]);
        let end = (t + sojourn).min(duration);
        let mut a = t;
        loop {
            a += rng.exp(rates[state]);
            if a >= end {
                break;
            }
            arrivals.push(a);
        }
        t = end;
        if rates.len() > 1 {
            let mut next = rng.usize(rates.len() - 1);
            if next >= state {
                next += 1;
            }
            state = next;
        }
    }
    Trace::new(arrivals)
}

/// The diurnal rate closure, shared by the materialized generator and
/// the streaming source so both evaluate bit-identical rates.
fn diurnal_rate(base: f64, amplitude: f64, period: f64) -> impl Fn(f64) -> f64 {
    let omega = 2.0 * std::f64::consts::PI / period;
    move |t| base * (1.0 + amplitude * (omega * t).sin())
}

/// Diurnal (sinusoidal) rate curve:
/// λ(t) = base · (1 + amplitude · sin(2πt / period)), Gamma(cv)
/// inter-arrivals. `amplitude` in [0, 1) keeps the rate positive.
pub fn diurnal_trace(
    base: f64,
    amplitude: f64,
    period: f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(base > 0.0 && (0.0..1.0).contains(&amplitude) && period > 0.0);
    rate_curve_trace(diurnal_rate(base, amplitude, period), cv, duration, seed)
}

/// The flash-crowd rate closure, shared by the materialized generator
/// and the streaming source so both evaluate bit-identical rates.
fn flash_crowd_rate(
    base: f64,
    peak: f64,
    start: f64,
    ramp: f64,
    hold: f64,
    decay: f64,
) -> impl Fn(f64) -> f64 {
    move |t| {
        if t < start {
            base
        } else if t < start + ramp {
            base + (peak - base) * (t - start) / ramp
        } else if t < start + ramp + hold {
            peak
        } else if t < start + ramp + hold + decay {
            peak - (peak - base) * (t - start - ramp - hold) / decay
        } else {
            base
        }
    }
}

/// Flash crowd: baseline `base` QPS, then a spike at `start` that ramps
/// linearly to `peak` over `ramp` seconds, holds for `hold` seconds and
/// decays linearly back over `decay` seconds.
#[allow(clippy::too_many_arguments)]
pub fn flash_crowd_trace(
    base: f64,
    peak: f64,
    start: f64,
    ramp: f64,
    hold: f64,
    decay: f64,
    cv: f64,
    duration: f64,
    seed: u64,
) -> Trace {
    assert!(base > 0.0 && peak > 0.0 && start >= 0.0);
    assert!(ramp >= 0.0 && hold >= 0.0 && decay >= 0.0);
    rate_curve_trace(
        flash_crowd_rate(base, peak, start, ramp, hold, decay),
        cv,
        duration,
        seed,
    )
}

/// Renewal process with Pareto inter-arrivals: shape α > 1 (finite mean),
/// scale chosen so the mean rate is `lambda`. Small α (1 < α ≲ 2) gives
/// the heavy tail — rare but enormous gaps between dense packs of
/// arrivals.
pub fn pareto_trace(lambda: f64, shape: f64, duration: f64, seed: u64) -> Trace {
    assert!(lambda > 0.0 && shape > 1.0 && duration > 0.0);
    // E[X] = α·x_m / (α − 1) = 1/λ  ⇒  x_m = (α − 1) / (α·λ).
    let xm = (shape - 1.0) / (shape * lambda);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += xm / rng.f64_open().powf(1.0 / shape);
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

/// Renewal process with lognormal inter-arrivals: log-σ `sigma`, log-μ
/// chosen so the mean rate is `lambda` (μ = −ln λ − σ²/2). σ ≳ 1.5 gives
/// inter-arrival CVs well above the Gamma traces the paper studies.
pub fn lognormal_trace(lambda: f64, sigma: f64, duration: f64, seed: u64) -> Trace {
    assert!(lambda > 0.0 && sigma > 0.0 && duration > 0.0);
    let mu = -lambda.ln() - sigma * sigma / 2.0;
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += (mu + sigma * rng.normal()).exp();
        if t > duration {
            break;
        }
        arrivals.push(t);
    }
    Trace::new(arrivals)
}

// ---------------------------------------------------------------------------
// Operators
// ---------------------------------------------------------------------------

/// Superpose (merge) several traces into one arrival stream.
pub fn superpose(traces: &[Trace]) -> Trace {
    Trace::from_unsorted(
        traces.iter().flat_map(|t| t.arrivals.iter().copied()).collect(),
    )
}

/// Splice traces back-to-back: each subsequent trace is shifted to start
/// where the previous one ended.
pub fn splice(traces: &[Trace]) -> Trace {
    traces.iter().fold(Trace::default(), |acc, t| acc.concat(t))
}

/// Bernoulli thinning: keep each arrival independently with probability
/// `p` (models subsampled or partially migrated traffic).
pub fn thin(trace: &Trace, p: f64, seed: u64) -> Trace {
    assert!((0.0..=1.0).contains(&p), "thin probability {p}");
    let mut rng = Rng::new(seed);
    Trace::new(trace.arrivals.iter().copied().filter(|_| rng.bool(p)).collect())
}

/// Probabilistic crossfade: play `a` in full, then hand traffic over to
/// `b` across the trailing `overlap` seconds of `a` — inside the window
/// each `a`-arrival survives with the fraction of the window remaining
/// and each `b`-arrival with the fraction elapsed, so the mix shifts
/// linearly from pure `a` to pure `b`. `b` is rebased to start at the
/// beginning of the window and continues after `a` ends.
pub fn ramp_between(a: &Trace, b: &Trace, overlap: f64, seed: u64) -> Trace {
    assert!(overlap >= 0.0);
    let a_end = a.arrivals.last().copied().unwrap_or(0.0);
    let t0 = (a_end - overlap).max(0.0);
    let window = (a_end - t0).max(f64::MIN_POSITIVE);
    let mut rng = Rng::new(seed);
    let mut arrivals = Vec::with_capacity(a.len() + b.len());
    for &t in &a.arrivals {
        let fade = ((t - t0) / window).clamp(0.0, 1.0);
        if fade <= 0.0 || rng.bool(1.0 - fade) {
            arrivals.push(t);
        }
    }
    for &t in &b.arrivals {
        let shifted = t0 + t;
        let fade = ((shifted - t0) / window).clamp(0.0, 1.0);
        if fade >= 1.0 || rng.bool(fade) {
            arrivals.push(shifted);
        }
    }
    Trace::from_unsorted(arrivals)
}

/// Rescale time by `factor` (> 1 stretches the trace and divides the
/// rate; < 1 compresses it and multiplies the rate).
pub fn rescale_time(trace: &Trace, factor: f64) -> Trace {
    assert!(factor > 0.0);
    Trace::new(trace.arrivals.iter().map(|&t| t * factor).collect())
}

/// Rescale time so the trace's mean rate becomes `target_qps`.
pub fn rescale_to_rate(trace: &Trace, target_qps: f64) -> Trace {
    assert!(target_qps > 0.0);
    let rate = trace.mean_rate();
    if rate <= 0.0 {
        return trace.clone();
    }
    rescale_time(trace, rate / target_qps)
}

/// Post-process a replayed trace (`replay` / `autoscale` nodes):
/// compress or stretch time, then pin the mean rate if requested.
fn apply_replay_scaling(mut trace: Trace, time_scale: f64, target_rate: Option<f64>) -> Trace {
    if (time_scale - 1.0).abs() > 1e-12 {
        trace = rescale_time(&trace, time_scale);
    }
    if let Some(target) = target_rate {
        trace = rescale_to_rate(&trace, target);
    }
    trace
}

// ---------------------------------------------------------------------------
// Declarative scenario tree
// ---------------------------------------------------------------------------

/// A declarative scenario node: a generator leaf or a composition
/// operator over sub-scenarios. Built from JSON by [`Scenario::parse`]
/// and realized into a [`Trace`] by [`Scenario::build`].
#[derive(Debug, Clone, PartialEq)]
pub enum Scenario {
    Gamma { lambda: f64, cv: f64, duration: f64 },
    Mmpp { rates: Vec<f64>, dwell: Vec<f64>, duration: f64 },
    Diurnal { base: f64, amplitude: f64, period: f64, cv: f64, duration: f64 },
    FlashCrowd {
        base: f64,
        peak: f64,
        start: f64,
        ramp: f64,
        hold: f64,
        decay: f64,
        cv: f64,
        duration: f64,
    },
    Pareto { lambda: f64, shape: f64, duration: f64 },
    Lognormal { lambda: f64, sigma: f64, duration: f64 },
    Replay { path: String, time_scale: f64, target_rate: Option<f64> },
    /// Replay of one of the paper's AutoScale-derived workloads
    /// ([`crate::workload::autoscale`]), synthesized at `max_qps` peak
    /// and optionally compressed / rescaled like [`Scenario::Replay`].
    /// Unlike a `replay` file node it needs no on-disk trace, so
    /// checked-in scenario specs can reference the paper workloads.
    AutoScale { workload: String, max_qps: f64, time_scale: f64, target_rate: Option<f64> },
    /// Production-trace replay ([`crate::workload::production`]): a
    /// per-minute invocation CSV (Azure-Functions-style) fitted to a
    /// piecewise-constant Gamma renewal process and resampled. `path`
    /// is an on-disk CSV or a compiled-in `builtin:` fixture;
    /// `max_qps` peak-rescales the series (after `limit_minutes`
    /// truncation) the way the autoscale workloads are pinned.
    Production {
        path: String,
        cv: f64,
        max_qps: Option<f64>,
        limit_minutes: Option<usize>,
    },
    Superpose(Vec<Scenario>),
    Splice(Vec<Scenario>),
    Thin { p: f64, of: Box<Scenario> },
    RampBetween { from: Box<Scenario>, to: Box<Scenario>, overlap: f64 },
}

/// Range check performed at parse time, so a malformed-but-numeric spec
/// surfaces as a CLI error (naming the node at `path`) instead of
/// tripping a generator assertion.
fn check(cond: bool, path: &str, what: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(format!("{path}: out of range: {what}"))
    }
}

fn opt_num(node: &Json, key: &str, default: f64, path: &str) -> Result<f64, String> {
    match node.get(key) {
        None => Ok(default),
        Some(v) => v
            .as_f64()
            .ok_or_else(|| format!("{path}: field {key:?} must be a number")),
    }
}

fn req_str(node: &Json, key: &str, path: &str) -> Result<String, String> {
    node.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("{path}: missing string field {key:?}"))
}

fn num_array(node: &Json, key: &str, path: &str) -> Result<Vec<f64>, String> {
    let arr = node
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: missing array field {key:?}"))?;
    arr.iter()
        .map(|v| v.as_f64().ok_or_else(|| format!("{path}: {key:?} must contain numbers")))
        .collect()
}

fn node_list(node: &Json, key: &str, path: &str) -> Result<Vec<Scenario>, String> {
    let arr = node
        .get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: missing array field {key:?}"))?;
    if arr.is_empty() {
        return Err(format!("{path}: field {key:?} must not be empty"));
    }
    arr.iter()
        .enumerate()
        .map(|(i, v)| Scenario::parse_at(v, &format!("{path}.{key}[{i}]")))
        .collect()
}

/// Shared `time_scale` / `target_rate` fields of the replay-style kinds
/// (`replay`, `autoscale`). Mutually exclusive: `rescale_to_rate`
/// renormalizes the mean rate after any time scaling, which would erase
/// a `time_scale` exactly and silently — reject the combination at
/// parse instead.
fn replay_scaling(node: &Json, path: &str, kind: &str) -> Result<(f64, Option<f64>), String> {
    let time_scale = opt_num(node, "time_scale", 1.0, path)?;
    check(time_scale > 0.0, path, &format!("{kind} time_scale must be > 0"))?;
    let target_rate = opt_f64_at(node, "target_rate", path)?;
    check(
        target_rate.map_or(true, |r| r > 0.0),
        path,
        &format!("{kind} target_rate must be > 0"),
    )?;
    if (time_scale - 1.0).abs() > 1e-12 && target_rate.is_some() {
        return Err(format!(
            "{path}: {kind} \"time_scale\" and \"target_rate\" are mutually exclusive \
             (target_rate renormalizes the mean rate, erasing time_scale exactly)"
        ));
    }
    Ok((time_scale, target_rate))
}

fn sub_node(node: &Json, key: &str, path: &str) -> Result<Box<Scenario>, String> {
    let sub = node
        .get(key)
        .ok_or_else(|| format!("{path}: missing field {key:?}"))?;
    Ok(Box::new(Scenario::parse_at(sub, &format!("{path}.{key}"))?))
}

impl Scenario {
    /// Parse one scenario node from its JSON form (see the module docs
    /// for the schema). Errors name the offending node by its path from
    /// the document root.
    pub fn parse(node: &Json) -> Result<Scenario, String> {
        Self::parse_at(node, "scenario")
    }

    fn parse_at(node: &Json, path: &str) -> Result<Scenario, String> {
        let kind = node
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("{path}: missing string field \"kind\""))?;
        match kind {
            "gamma" => {
                let lambda = req_num(node, "lambda", path)?;
                let cv = opt_num(node, "cv", 1.0, path)?;
                let duration = req_num(node, "duration", path)?;
                check(lambda > 0.0, path, "gamma lambda must be > 0")?;
                check(cv > 0.0, path, "gamma cv must be > 0")?;
                check(duration > 0.0, path, "gamma duration must be > 0")?;
                Ok(Scenario::Gamma { lambda, cv, duration })
            }
            "mmpp" => {
                let rates = num_array(node, "rates", path)?;
                let dwell = num_array(node, "dwell", path)?;
                if rates.is_empty() || rates.len() != dwell.len() {
                    return Err(format!(
                        "{path}: mmpp needs matching non-empty \"rates\" and \"dwell\""
                    ));
                }
                let duration = req_num(node, "duration", path)?;
                check(rates.iter().all(|&r| r > 0.0), path, "mmpp rates must be > 0")?;
                check(dwell.iter().all(|&d| d > 0.0), path, "mmpp dwell must be > 0")?;
                check(duration > 0.0, path, "mmpp duration must be > 0")?;
                Ok(Scenario::Mmpp { rates, dwell, duration })
            }
            "diurnal" => {
                let base = req_num(node, "base", path)?;
                let amplitude = req_num(node, "amplitude", path)?;
                let period = req_num(node, "period", path)?;
                let cv = opt_num(node, "cv", 1.0, path)?;
                let duration = req_num(node, "duration", path)?;
                check(base > 0.0, path, "diurnal base must be > 0")?;
                check(
                    (0.0..1.0).contains(&amplitude),
                    path,
                    "diurnal amplitude must be in [0, 1)",
                )?;
                check(period > 0.0 && cv > 0.0, path, "diurnal period and cv must be > 0")?;
                check(duration > 0.0, path, "diurnal duration must be > 0")?;
                Ok(Scenario::Diurnal { base, amplitude, period, cv, duration })
            }
            "flash_crowd" => {
                let base = req_num(node, "base", path)?;
                let peak = req_num(node, "peak", path)?;
                let start = req_num(node, "start", path)?;
                let ramp = opt_num(node, "ramp", 1.0, path)?;
                let hold = req_num(node, "hold", path)?;
                let decay = opt_num(node, "decay", 1.0, path)?;
                let cv = opt_num(node, "cv", 1.0, path)?;
                let duration = req_num(node, "duration", path)?;
                check(base > 0.0 && peak > 0.0, path, "flash_crowd rates must be > 0")?;
                check(
                    start >= 0.0 && ramp >= 0.0 && hold >= 0.0 && decay >= 0.0,
                    path,
                    "flash_crowd phases must be >= 0",
                )?;
                check(
                    cv > 0.0 && duration > 0.0,
                    path,
                    "flash_crowd cv and duration must be > 0",
                )?;
                Ok(Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration })
            }
            "pareto" => {
                let lambda = req_num(node, "lambda", path)?;
                let shape = req_num(node, "shape", path)?;
                let duration = req_num(node, "duration", path)?;
                check(lambda > 0.0, path, "pareto lambda must be > 0")?;
                check(shape > 1.0, path, "pareto shape must be > 1 (finite mean)")?;
                check(duration > 0.0, path, "pareto duration must be > 0")?;
                Ok(Scenario::Pareto { lambda, shape, duration })
            }
            "lognormal" => {
                let lambda = req_num(node, "lambda", path)?;
                let sigma = req_num(node, "sigma", path)?;
                let duration = req_num(node, "duration", path)?;
                check(
                    lambda > 0.0 && sigma > 0.0,
                    path,
                    "lognormal lambda and sigma must be > 0",
                )?;
                check(duration > 0.0, path, "lognormal duration must be > 0")?;
                Ok(Scenario::Lognormal { lambda, sigma, duration })
            }
            "replay" => {
                let file = req_str(node, "path", path)?;
                let (time_scale, target_rate) = replay_scaling(node, path, "replay")?;
                Ok(Scenario::Replay { path: file, time_scale, target_rate })
            }
            "autoscale" => {
                let workload = req_str(node, "workload", path)?;
                if !matches!(workload.as_str(), "big_spike" | "instant_spike") {
                    return Err(format!(
                        "{path}: unknown autoscale workload {workload:?} \
                         (expected \"big_spike\" or \"instant_spike\")"
                    ));
                }
                let max_qps = req_num(node, "max_qps", path)?;
                check(max_qps > 0.0, path, "autoscale max_qps must be > 0")?;
                let (time_scale, target_rate) = replay_scaling(node, path, "autoscale")?;
                Ok(Scenario::AutoScale { workload, max_qps, time_scale, target_rate })
            }
            "production" => {
                let file = req_str(node, "path", path)?;
                let cv = opt_num(node, "cv", 1.0, path)?;
                check(cv > 0.0, path, "production cv must be > 0")?;
                let max_qps = opt_f64_at(node, "max_qps", path)?;
                check(
                    max_qps.map_or(true, |m| m > 0.0),
                    path,
                    "production max_qps must be > 0",
                )?;
                let limit = opt_f64_at(node, "limit_minutes", path)?;
                check(
                    limit.map_or(true, |l| l >= 1.0 && l.fract() == 0.0),
                    path,
                    "production limit_minutes must be a positive integer",
                )?;
                Ok(Scenario::Production {
                    path: file,
                    cv,
                    max_qps,
                    limit_minutes: limit.map(|l| l as usize),
                })
            }
            "superpose" => Ok(Scenario::Superpose(node_list(node, "of", path)?)),
            "splice" => Ok(Scenario::Splice(node_list(node, "of", path)?)),
            "thin" => {
                let p = req_num(node, "p", path)?;
                check((0.0..=1.0).contains(&p), path, "thin p must be in [0, 1]")?;
                Ok(Scenario::Thin { p, of: sub_node(node, "of", path)? })
            }
            "ramp_between" => {
                let overlap = req_num(node, "overlap", path)?;
                check(overlap >= 0.0, path, "ramp_between overlap must be >= 0")?;
                Ok(Scenario::RampBetween {
                    from: sub_node(node, "from", path)?,
                    to: sub_node(node, "to", path)?,
                    overlap,
                })
            }
            other => Err(format!("{path}: unknown scenario kind {other:?}")),
        }
    }

    /// Realize the scenario into an arrival trace. Deterministic in
    /// (self, seed): every child derives its sub-seed via [`child_seed`],
    /// so sibling subtrees have independent but reproducible streams.
    pub fn build(&self, seed: u64) -> Result<Trace, String> {
        match self {
            Scenario::Gamma { lambda, cv, duration } => {
                Ok(super::gamma_trace(*lambda, *cv, *duration, seed))
            }
            Scenario::Mmpp { rates, dwell, duration } => {
                Ok(mmpp_trace(rates, dwell, *duration, seed))
            }
            Scenario::Diurnal { base, amplitude, period, cv, duration } => {
                Ok(diurnal_trace(*base, *amplitude, *period, *cv, *duration, seed))
            }
            Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration } => {
                Ok(flash_crowd_trace(
                    *base, *peak, *start, *ramp, *hold, *decay, *cv, *duration, seed,
                ))
            }
            Scenario::Pareto { lambda, shape, duration } => {
                Ok(pareto_trace(*lambda, *shape, *duration, seed))
            }
            Scenario::Lognormal { lambda, sigma, duration } => {
                Ok(lognormal_trace(*lambda, *sigma, *duration, seed))
            }
            Scenario::Replay { path, time_scale, target_rate } => {
                let trace = Trace::load(Path::new(path))?;
                Ok(apply_replay_scaling(trace, *time_scale, *target_rate))
            }
            Scenario::AutoScale { workload, max_qps, time_scale, target_rate } => {
                let minutes = match workload.as_str() {
                    "big_spike" => super::autoscale::big_spike_minutes(),
                    "instant_spike" => super::autoscale::instant_spike_minutes(),
                    other => return Err(format!("unknown autoscale workload {other:?}")),
                };
                let trace = super::autoscale::synthesize(&minutes, *max_qps, seed);
                Ok(apply_replay_scaling(trace, *time_scale, *target_rate))
            }
            Scenario::Production { path, cv, max_qps, limit_minutes } => {
                let rates =
                    super::production::resolve_rates(path, *max_qps, *limit_minutes)?;
                let duration = rates.len() as f64 * 60.0;
                Ok(rate_curve_trace(
                    |t| super::production::rate_at(&rates, t),
                    *cv,
                    duration,
                    seed,
                ))
            }
            Scenario::Superpose(parts) => {
                let traces = parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.build(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(superpose(&traces))
            }
            Scenario::Splice(parts) => {
                let traces = parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.build(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?;
                Ok(splice(&traces))
            }
            Scenario::Thin { p, of } => {
                let inner = of.build(child_seed(seed, 0))?;
                Ok(thin(&inner, *p, child_seed(seed, 1)))
            }
            Scenario::RampBetween { from, to, overlap } => {
                let a = from.build(child_seed(seed, 0))?;
                let b = to.build(child_seed(seed, 1))?;
                Ok(ramp_between(&a, &b, *overlap, child_seed(seed, 2)))
            }
        }
    }

    /// The streaming form of [`Scenario::build`]: a chunked
    /// [`ArrivalSource`](super::stream::ArrivalSource) whose
    /// concatenated chunks are **bit-identical** to the materialized
    /// trace for the same (self, seed), for any chunk-size sequence —
    /// the determinism contract of [`super::stream`], enforced across
    /// the whole checked-in scenario grid by
    /// `rust/tests/streaming_conformance.rs`.
    ///
    /// Child seeds derive exactly as in `build` ([`child_seed`] with
    /// the same tags), so a subtree streams the same bytes whether its
    /// siblings are streamed or materialized. `replay`, `autoscale`
    /// and `ramp_between` nodes materialize internally (fixed-horizon
    /// replays, and a crossfade anchored on the `from` trace's last
    /// arrival) and stream from the buffer; every other kind streams
    /// in O(chunk) memory.
    pub fn source(
        &self,
        seed: u64,
    ) -> Result<Box<dyn super::stream::ArrivalSource>, String> {
        use super::stream::{
            GammaSource, LognormalSource, MaterializedSource, MmppSource, ParetoSource,
            RateCurveSource, SpliceSource, SuperposeSource, ThinSource,
        };
        Ok(match self {
            Scenario::Gamma { lambda, cv, duration } => {
                Box::new(GammaSource::new(*lambda, *cv, *duration, seed))
            }
            Scenario::Mmpp { rates, dwell, duration } => {
                Box::new(MmppSource::new(rates.clone(), dwell.clone(), *duration, seed))
            }
            Scenario::Diurnal { base, amplitude, period, cv, duration } => {
                Box::new(RateCurveSource::new(
                    Box::new(diurnal_rate(*base, *amplitude, *period)),
                    *cv,
                    *duration,
                    seed,
                ))
            }
            Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration } => {
                Box::new(RateCurveSource::new(
                    Box::new(flash_crowd_rate(*base, *peak, *start, *ramp, *hold, *decay)),
                    *cv,
                    *duration,
                    seed,
                ))
            }
            Scenario::Pareto { lambda, shape, duration } => {
                Box::new(ParetoSource::new(*lambda, *shape, *duration, seed))
            }
            Scenario::Lognormal { lambda, sigma, duration } => {
                Box::new(LognormalSource::new(*lambda, *sigma, *duration, seed))
            }
            Scenario::Production { path, cv, max_qps, limit_minutes } => {
                let rates =
                    super::production::resolve_rates(path, *max_qps, *limit_minutes)?;
                let duration = rates.len() as f64 * 60.0;
                Box::new(RateCurveSource::new(
                    Box::new(move |t| super::production::rate_at(&rates, t)),
                    *cv,
                    duration,
                    seed,
                ))
            }
            // Fixed-horizon replays and the crossfade (anchored on the
            // `from` trace's last arrival) materialize internally.
            Scenario::Replay { .. }
            | Scenario::AutoScale { .. }
            | Scenario::RampBetween { .. } => {
                Box::new(MaterializedSource::new(self.build(seed)?))
            }
            Scenario::Superpose(parts) => Box::new(SuperposeSource::new(
                parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.source(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?,
            )),
            Scenario::Splice(parts) => Box::new(SpliceSource::new(
                parts
                    .iter()
                    .enumerate()
                    .map(|(i, p)| p.source(child_seed(seed, i as u64)))
                    .collect::<Result<Vec<_>, _>>()?,
            )),
            Scenario::Thin { p, of } => Box::new(ThinSource::new(
                of.source(child_seed(seed, 0))?,
                *p,
                child_seed(seed, 1),
            )),
        })
    }

    /// Compress the scenario's *schedule* by `factor` (< 1 shortens):
    /// every duration, period, phase boundary, dwell time and overlap is
    /// scaled while rates are left untouched, so a 600 s scenario at
    /// 100 QPS becomes a 120 s scenario at 100 QPS with the same shape.
    /// This is how quick (CI) mode derives its matrix from the
    /// checked-in full-mode specs. Replayed timelines
    /// ([`Scenario::Replay`] / [`Scenario::AutoScale`] /
    /// [`Scenario::Production`]) keep their own horizon — compressing
    /// them would multiply the rate instead — so specs built on them
    /// declare an explicit `"quick"` node (`production` nodes can
    /// shorten via `limit_minutes`).
    pub fn scaled(&self, factor: f64) -> Scenario {
        assert!(factor > 0.0, "scale factor {factor}");
        match self {
            Scenario::Gamma { lambda, cv, duration } => {
                Scenario::Gamma { lambda: *lambda, cv: *cv, duration: duration * factor }
            }
            Scenario::Mmpp { rates, dwell, duration } => Scenario::Mmpp {
                rates: rates.clone(),
                dwell: dwell.iter().map(|d| d * factor).collect(),
                duration: duration * factor,
            },
            Scenario::Diurnal { base, amplitude, period, cv, duration } => Scenario::Diurnal {
                base: *base,
                amplitude: *amplitude,
                period: period * factor,
                cv: *cv,
                duration: duration * factor,
            },
            Scenario::FlashCrowd { base, peak, start, ramp, hold, decay, cv, duration } => {
                Scenario::FlashCrowd {
                    base: *base,
                    peak: *peak,
                    start: start * factor,
                    ramp: ramp * factor,
                    hold: hold * factor,
                    decay: decay * factor,
                    cv: *cv,
                    duration: duration * factor,
                }
            }
            Scenario::Pareto { lambda, shape, duration } => {
                Scenario::Pareto { lambda: *lambda, shape: *shape, duration: duration * factor }
            }
            Scenario::Lognormal { lambda, sigma, duration } => Scenario::Lognormal {
                lambda: *lambda,
                sigma: *sigma,
                duration: duration * factor,
            },
            Scenario::Replay { .. }
            | Scenario::AutoScale { .. }
            | Scenario::Production { .. } => self.clone(),
            Scenario::Superpose(parts) => {
                Scenario::Superpose(parts.iter().map(|p| p.scaled(factor)).collect())
            }
            Scenario::Splice(parts) => {
                Scenario::Splice(parts.iter().map(|p| p.scaled(factor)).collect())
            }
            Scenario::Thin { p, of } => {
                Scenario::Thin { p: *p, of: Box::new(of.scaled(factor)) }
            }
            Scenario::RampBetween { from, to, overlap } => Scenario::RampBetween {
                from: Box::new(from.scaled(factor)),
                to: Box::new(to.scaled(factor)),
                overlap: overlap * factor,
            },
        }
    }
}

/// A named, seeded scenario document: the on-disk unit the CLI loads.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    pub seed: u64,
    pub scenario: Scenario,
    /// Optional explicit quick-mode (CI) scenario. When absent, quick
    /// mode serves `scenario.scaled(Self::QUICK_FACTOR)`.
    pub quick: Option<Scenario>,
    /// Optional fault-injection spec (chaos families): the failure
    /// schedule served alongside the arrival schedule. Quick mode
    /// compresses fault times by the same [`Self::QUICK_FACTOR`], so
    /// faults keep landing at the same *relative* points of the run.
    pub faults: Option<crate::simulator::faults::FaultSpec>,
}

impl ScenarioSpec {
    /// Schedule-compression factor quick mode applies to specs without
    /// an explicit `"quick"` node (600 s full scenarios become 120 s).
    pub const QUICK_FACTOR: f64 = 0.2;

    /// Parse a full spec document (`{"name", "seed", "scenario",
    /// "quick"?, "faults"?}`; name defaults to `"scenario"`, seed to 42).
    pub fn parse(doc: &Json) -> Result<ScenarioSpec, String> {
        let scenario = doc
            .get("scenario")
            .ok_or("spec missing field \"scenario\"")?;
        let quick = match doc.get("quick") {
            None => None,
            Some(q) => Some(Scenario::parse_at(q, "quick")?),
        };
        let faults = match doc.get("faults") {
            None => None,
            Some(f) => Some(crate::simulator::faults::FaultSpec::parse_at(f, "faults")?),
        };
        Ok(ScenarioSpec {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("scenario")
                .to_string(),
            seed: doc.get("seed").and_then(Json::as_f64).unwrap_or(42.0) as u64,
            scenario: Scenario::parse(scenario)?,
            quick,
            faults,
        })
    }

    /// The scenario to serve in the given mode: the full node, the
    /// explicit quick node, or the schedule-compressed full node (see
    /// [`Scenario::scaled`]).
    pub fn scenario_for(&self, quick: bool) -> Scenario {
        if !quick {
            return self.scenario.clone();
        }
        match &self.quick {
            Some(q) => q.clone(),
            None => self.scenario.scaled(Self::QUICK_FACTOR),
        }
    }

    /// The fault spec to serve in the given mode: quick mode compresses
    /// the failure schedule with the same factor as the arrival schedule
    /// (explicit `"quick"` scenario nodes don't change this — a chaos
    /// spec should rely on uniform compression so faults and traffic
    /// stay aligned; see `scenarios/README.md`).
    pub fn faults_for(&self, quick: bool) -> Option<crate::simulator::faults::FaultSpec> {
        self.faults.as_ref().map(|f| {
            if quick {
                f.scaled(Self::QUICK_FACTOR)
            } else {
                f.clone()
            }
        })
    }

    pub fn parse_str(text: &str) -> Result<ScenarioSpec, String> {
        Self::parse(&Json::parse(text)?)
    }

    pub fn load(path: &Path) -> Result<ScenarioSpec, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("{}: {e}", path.display()))?;
        Self::parse_str(&text).map_err(|e| format!("{}: {e}", path.display()))
    }

    /// Build the trace with the spec's own seed.
    pub fn build(&self) -> Result<Trace, String> {
        self.scenario.build(self.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::gamma_trace;

    fn window_rate(tr: &Trace, lo: f64, hi: f64) -> f64 {
        let n = tr.arrivals.iter().filter(|&&t| t >= lo && t < hi).count();
        n as f64 / (hi - lo)
    }

    #[test]
    fn child_seed_is_stable_and_spreads() {
        assert_eq!(child_seed(7, 0), child_seed(7, 0));
        assert_ne!(child_seed(7, 0), child_seed(7, 1));
        assert_ne!(child_seed(7, 0), child_seed(8, 0));
    }

    #[test]
    fn mmpp_is_deterministic_and_bursty() {
        let rates = [20.0, 300.0];
        let dwell = [15.0, 15.0];
        let a = mmpp_trace(&rates, &dwell, 300.0, 3);
        let b = mmpp_trace(&rates, &dwell, 300.0, 3);
        assert_eq!(a, b);
        assert_ne!(a, mmpp_trace(&rates, &dwell, 300.0, 4));
        // Mean rate between the state rates; CV well above Poisson.
        assert!(a.mean_rate() > 30.0 && a.mean_rate() < 290.0, "rate {}", a.mean_rate());
        assert!(a.cv() > 1.1, "cv {}", a.cv());
    }

    #[test]
    fn diurnal_peaks_and_troughs() {
        let tr = diurnal_trace(100.0, 0.5, 120.0, 1.0, 240.0, 5);
        // sin peaks at t=30 (+mod period), troughs at t=90.
        let peak = window_rate(&tr, 15.0, 45.0) + window_rate(&tr, 135.0, 165.0);
        let trough = window_rate(&tr, 75.0, 105.0) + window_rate(&tr, 195.0, 225.0);
        assert!(peak > 1.5 * trough, "peak {peak} vs trough {trough}");
        assert_eq!(tr, diurnal_trace(100.0, 0.5, 120.0, 1.0, 240.0, 5));
    }

    #[test]
    fn flash_crowd_hits_peak_then_recovers() {
        let tr = flash_crowd_trace(100.0, 400.0, 60.0, 5.0, 30.0, 15.0, 1.0, 180.0, 7);
        let before = window_rate(&tr, 10.0, 55.0);
        let during = window_rate(&tr, 66.0, 94.0);
        let after = window_rate(&tr, 130.0, 175.0);
        assert!((before - 100.0).abs() < 25.0, "before {before}");
        assert!((during - 400.0).abs() < 80.0, "during {during}");
        assert!((after - 100.0).abs() < 25.0, "after {after}");
    }

    #[test]
    fn pareto_has_heavy_tail() {
        let tr = pareto_trace(100.0, 1.6, 120.0, 9);
        assert!(tr.mean_rate() > 40.0 && tr.mean_rate() < 200.0, "rate {}", tr.mean_rate());
        // Tail heaviness: the p99 inter-arrival dwarfs the median
        // (theoretical ratio 50^(1/1.6) ≈ 11.5 for Pareto).
        let mut gaps: Vec<f64> = tr.arrivals.windows(2).map(|w| w[1] - w[0]).collect();
        gaps.sort_by(f64::total_cmp);
        let median = gaps[gaps.len() / 2];
        let p99 = gaps[gaps.len() * 99 / 100];
        assert!(p99 > 5.0 * median, "p99 {p99} vs median {median}");
    }

    #[test]
    fn lognormal_matches_rate_with_high_cv() {
        let tr = lognormal_trace(100.0, 1.5, 120.0, 11);
        assert!((tr.mean_rate() - 100.0).abs() < 25.0, "rate {}", tr.mean_rate());
        assert!(tr.cv() > 1.3, "cv {}", tr.cv());
    }

    #[test]
    fn superpose_adds_rates_and_sorts() {
        let a = gamma_trace(50.0, 1.0, 60.0, 1);
        let b = gamma_trace(50.0, 1.0, 60.0, 2);
        let merged = superpose(&[a.clone(), b.clone()]);
        assert_eq!(merged.len(), a.len() + b.len());
        assert!(merged.arrivals.windows(2).all(|w| w[0] <= w[1]));
        assert!((merged.mean_rate() - 100.0).abs() < 15.0, "rate {}", merged.mean_rate());
    }

    #[test]
    fn thin_keeps_expected_fraction() {
        let tr = gamma_trace(100.0, 1.0, 60.0, 13);
        let half = thin(&tr, 0.5, 17);
        let frac = half.len() as f64 / tr.len() as f64;
        assert!((frac - 0.5).abs() < 0.08, "kept {frac}");
        assert_eq!(half, thin(&tr, 0.5, 17));
        assert_eq!(thin(&tr, 1.0, 1).len(), tr.len());
        assert_eq!(thin(&tr, 0.0, 1).len(), 0);
    }

    #[test]
    fn splice_concatenates_durations() {
        let a = gamma_trace(80.0, 1.0, 30.0, 19);
        let b = gamma_trace(20.0, 1.0, 30.0, 23);
        let joined = splice(&[a.clone(), b.clone()]);
        assert_eq!(joined.len(), a.len() + b.len());
        assert!(joined.arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn ramp_between_crossfades() {
        let a = gamma_trace(200.0, 1.0, 60.0, 29);
        let b = gamma_trace(50.0, 1.0, 60.0, 31);
        let tr = ramp_between(&a, &b, 20.0, 37);
        assert!(tr.arrivals.windows(2).all(|w| w[0] <= w[1]));
        let early = window_rate(&tr, 0.0, 35.0);
        let late = window_rate(&tr, 65.0, 95.0);
        assert!(early > 2.0 * late, "early {early} late {late}");
    }

    #[test]
    fn rescale_changes_rate() {
        let tr = gamma_trace(100.0, 1.0, 60.0, 41);
        let double = rescale_time(&tr, 0.5);
        assert!((double.mean_rate() - 2.0 * tr.mean_rate()).abs() < 10.0);
        let target = rescale_to_rate(&tr, 40.0);
        assert!((target.mean_rate() - 40.0).abs() < 2.0, "rate {}", target.mean_rate());
    }

    #[test]
    fn spec_parses_and_builds_deterministically() {
        let text = r#"{
            "name": "composite",
            "seed": 9,
            "scenario": {
                "kind": "superpose",
                "of": [
                    {"kind": "gamma", "lambda": 60, "cv": 1.0, "duration": 60},
                    {"kind": "thin", "p": 0.5,
                     "of": {"kind": "mmpp", "rates": [30, 120], "dwell": [10, 10],
                            "duration": 60}}
                ]
            }
        }"#;
        let spec = ScenarioSpec::parse_str(text).unwrap();
        assert_eq!(spec.name, "composite");
        assert_eq!(spec.seed, 9);
        let a = spec.build().unwrap();
        let b = spec.build().unwrap();
        assert_eq!(a, b);
        assert!(!a.is_empty());
        // A different seed changes the realization.
        assert_ne!(a, spec.scenario.build(10).unwrap());
    }

    #[test]
    fn spec_parse_rejects_malformed_nodes() {
        for text in [
            r#"{"scenario": {"kind": "nope"}}"#,
            r#"{"scenario": {"kind": "gamma", "cv": 1.0}}"#,
            r#"{"scenario": {"kind": "mmpp", "rates": [1], "dwell": [], "duration": 10}}"#,
            r#"{"scenario": {"kind": "thin", "p": 0.5}}"#,
            r#"{"name": "no-scenario"}"#,
            // Numeric but out of range: must error at parse, not panic in
            // a generator assertion at build time.
            r#"{"scenario": {"kind": "gamma", "lambda": 0, "duration": 10}}"#,
            r#"{"scenario": {"kind": "mmpp", "rates": [0, 5], "dwell": [1, 1], "duration": 10}}"#,
            r#"{"scenario": {"kind": "diurnal", "base": 50, "amplitude": 1.5, "period": 60,
                "duration": 60}}"#,
            r#"{"scenario": {"kind": "pareto", "lambda": 50, "shape": 0.9, "duration": 10}}"#,
            r#"{"scenario": {"kind": "thin", "p": 1.5,
                "of": {"kind": "gamma", "lambda": 10, "duration": 5}}}"#,
        ] {
            assert!(ScenarioSpec::parse_str(text).is_err(), "{text}");
        }
    }

    #[test]
    fn parse_errors_name_the_offending_node() {
        let cases = [
            (
                r#"{"scenario": {"kind": "superpose", "of": [
                    {"kind": "gamma", "lambda": 60, "duration": 60},
                    {"kind": "mmpp", "rates": [0, 5], "dwell": [1, 1], "duration": 10}
                ]}}"#,
                "scenario.of[1]",
            ),
            (
                r#"{"scenario": {"kind": "thin", "p": 0.5,
                    "of": {"kind": "gamma", "cv": 1.0}}}"#,
                "scenario.of",
            ),
            (
                r#"{"scenario": {"kind": "ramp_between", "overlap": 5,
                    "from": {"kind": "gamma", "lambda": 10, "duration": 5},
                    "to": {"kind": "nope"}}}"#,
                "scenario.to",
            ),
            (
                r#"{"scenario": {"kind": "gamma", "lambda": 10, "duration": 5},
                    "quick": {"kind": "gamma", "lambda": -1, "duration": 5}}"#,
                "quick",
            ),
            (
                r#"{"scenario": {"kind": "autoscale", "workload": "huge_spike",
                    "max_qps": 50}}"#,
                "unknown autoscale workload",
            ),
        ];
        for (text, needle) in cases {
            let err = ScenarioSpec::parse_str(text).unwrap_err();
            assert!(err.contains(needle), "{err:?} should mention {needle:?}");
        }
    }

    #[test]
    fn autoscale_node_builds_and_rescales() {
        let spec = ScenarioSpec::parse_str(
            r#"{"scenario": {"kind": "autoscale", "workload": "big_spike",
                "max_qps": 40, "target_rate": 100}}"#,
        )
        .unwrap();
        let a = spec.scenario.build(3).unwrap();
        assert_eq!(a, spec.scenario.build(3).unwrap());
        assert_ne!(a, spec.scenario.build(4).unwrap());
        assert!((a.mean_rate() - 100.0).abs() < 5.0, "rate {}", a.mean_rate());
        // The big spike survives the rescale: the peak window rate is
        // well above the mean.
        assert!(a.peak_rate(10.0) > 1.5 * a.mean_rate(), "peak {}", a.peak_rate(10.0));
        // Malformed nodes are rejected at parse with the range named.
        for text in [
            r#"{"scenario": {"kind": "autoscale", "max_qps": 40}}"#,
            r#"{"scenario": {"kind": "autoscale", "workload": "big_spike", "max_qps": 0}}"#,
            r#"{"scenario": {"kind": "autoscale", "workload": "big_spike",
                "max_qps": 40, "target_rate": -5}}"#,
        ] {
            assert!(ScenarioSpec::parse_str(text).is_err(), "{text}");
        }
        // time_scale + target_rate together would be a silent no-op
        // (the rate renormalization erases the time scaling exactly), so
        // the combination is rejected at parse with both fields named.
        let err = ScenarioSpec::parse_str(
            r#"{"scenario": {"kind": "autoscale", "workload": "big_spike",
                "max_qps": 40, "time_scale": 0.2, "target_rate": 100}}"#,
        )
        .unwrap_err();
        assert!(err.contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn scaled_compresses_schedule_not_rates() {
        let full = Scenario::Splice(vec![
            Scenario::Gamma { lambda: 100.0, cv: 1.0, duration: 300.0 },
            Scenario::Diurnal {
                base: 100.0,
                amplitude: 0.5,
                period: 150.0,
                cv: 1.0,
                duration: 300.0,
            },
        ]);
        let quick = full.scaled(0.2);
        let tr = quick.build(5).unwrap();
        assert!(tr.duration() < 130.0, "duration {}", tr.duration());
        assert!((tr.mean_rate() - 100.0).abs() < 20.0, "rate {}", tr.mean_rate());
        // Replayed timelines are left untouched.
        let replay = Scenario::AutoScale {
            workload: "big_spike".into(),
            max_qps: 40.0,
            time_scale: 1.0,
            target_rate: Some(100.0),
        };
        assert_eq!(replay.scaled(0.2), replay);
    }

    #[test]
    fn explicit_quick_node_wins() {
        let spec = ScenarioSpec::parse_str(
            r#"{"seed": 3,
                "scenario": {"kind": "gamma", "lambda": 100, "duration": 600},
                "quick": {"kind": "gamma", "lambda": 100, "duration": 90}}"#,
        )
        .unwrap();
        assert_eq!(spec.scenario_for(false), spec.scenario);
        assert_eq!(
            spec.scenario_for(true),
            Scenario::Gamma { lambda: 100.0, cv: 1.0, duration: 90.0 }
        );
        // Without a quick node, quick mode compresses the schedule.
        let plain = ScenarioSpec::parse_str(
            r#"{"scenario": {"kind": "gamma", "lambda": 100, "duration": 600}}"#,
        )
        .unwrap();
        assert_eq!(
            plain.scenario_for(true),
            Scenario::Gamma { lambda: 100.0, cv: 1.0, duration: 120.0 }
        );
    }

    #[test]
    fn production_node_builds_resamples_and_rejects_malformed() {
        let spec = ScenarioSpec::parse_str(
            r#"{"scenario": {"kind": "production", "path": "builtin:azure-2021-sample",
                "cv": 1.0, "max_qps": 140, "limit_minutes": 5}}"#,
        )
        .unwrap();
        let a = spec.scenario.build(7).unwrap();
        assert_eq!(a, spec.scenario.build(7).unwrap());
        assert_ne!(a, spec.scenario.build(8).unwrap());
        // 5 minutes of piecewise-constant resampling, peak pinned to
        // 140 QPS over the served window.
        assert!(a.duration() > 250.0 && a.duration() <= 300.0, "duration {}", a.duration());
        assert!(a.mean_rate() > 50.0 && a.mean_rate() <= 160.0, "rate {}", a.mean_rate());
        // Fixed-horizon kind: schedule scaling leaves it untouched.
        assert_eq!(spec.scenario.scaled(0.2), spec.scenario);
        for text in [
            r#"{"scenario": {"kind": "production"}}"#,
            r#"{"scenario": {"kind": "production", "path": "builtin:azure-2021-sample",
                "cv": 0}}"#,
            r#"{"scenario": {"kind": "production", "path": "builtin:azure-2021-sample",
                "max_qps": -5}}"#,
            r#"{"scenario": {"kind": "production", "path": "builtin:azure-2021-sample",
                "limit_minutes": 2.5}}"#,
            r#"{"scenario": {"kind": "production", "path": "builtin:azure-2021-sample",
                "limit_minutes": 0}}"#,
        ] {
            assert!(ScenarioSpec::parse_str(text).is_err(), "{text}");
        }
        // Unknown builtins fail at build, naming the fixture.
        let bad = ScenarioSpec::parse_str(
            r#"{"scenario": {"kind": "production", "path": "builtin:nope"}}"#,
        )
        .unwrap();
        assert!(bad.scenario.build(1).unwrap_err().contains("unknown builtin"));
    }

    #[test]
    fn replay_node_rescales_a_saved_trace() {
        let dir = std::env::temp_dir().join("inferline-scenario-replay");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("replay.txt");
        gamma_trace(50.0, 1.0, 30.0, 43).save(&path).unwrap();
        let spec = ScenarioSpec::parse_str(&format!(
            r#"{{"scenario": {{"kind": "replay", "path": {:?}, "target_rate": 100}}}}"#,
            path.to_str().unwrap()
        ))
        .unwrap();
        let tr = spec.build().unwrap();
        assert!((tr.mean_rate() - 100.0).abs() < 5.0, "rate {}", tr.mean_rate());
    }
}
