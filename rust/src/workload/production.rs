//! Production-trace ingest: Azure-Functions-style per-minute invocation
//! counts, fitted to a renewal process and resampled as arrivals.
//!
//! Public FaaS traces (the Azure Functions 2021 release is the
//! canonical example) publish *per-minute invocation counts*, not
//! timestamps. The `production` scenario kind turns such a series into
//! a replayable arrival process the same way
//! [`super::autoscale::synthesize`] re-synthesizes the paper's
//! AutoScale workloads: each minute becomes a piecewise-constant rate
//! segment and inter-arrivals are drawn from a Gamma renewal process at
//! that rate (`cv` configurable, 1.0 = Poisson) via
//! [`super::scenarios::rate_curve_trace`] — so the resampled trace is
//! seed-deterministic, streams through the [`super::stream`] API
//! without materializing, and preserves the minute-scale shape of the
//! source workload.
//!
//! CSV schema (checked in under `scenarios/`, one optional header line):
//!
//! ```csv
//! minute,invocations
//! 0,4260
//! 1,3360
//! ```
//!
//! Minute indices must be consecutive from 0 (a gap in a per-minute
//! trace is a data bug, not a zero). `path` values with a `builtin:`
//! prefix resolve to fixtures compiled into the binary
//! ([`BUILTIN_PREFIX`]), so the robustness harness and CI need no
//! runtime file access.

/// Prefix marking a compiled-in fixture instead of an on-disk CSV.
pub const BUILTIN_PREFIX: &str = "builtin:";

/// 240 minutes of an Azure-Functions-style per-minute invocation
/// series: diurnal business ramp, lunchtime bulge, post-lunch dip and
/// two short bursts (deterministically synthesized — the real 2021
/// trace is multi-GB and cannot be vendored).
const AZURE_2021_SAMPLE: &str = include_str!("../../../scenarios/azure_2021_sample.csv");

/// Parse a per-minute invocation CSV into counts (index = minute).
pub fn parse_minutes_csv(text: &str) -> Result<Vec<f64>, String> {
    let mut counts: Vec<f64> = Vec::new();
    let mut seen_data = false;
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        let lineno = idx + 1;
        let mut fields = line.split(',');
        let (minute, count) = match (fields.next(), fields.next(), fields.next()) {
            (Some(m), Some(c), None) => (m.trim(), c.trim()),
            _ => {
                return Err(format!(
                    "line {lineno}: expected \"minute,invocations\", got {line:?}"
                ))
            }
        };
        if !seen_data && minute.parse::<f64>().is_err() {
            // One optional header line before the data.
            continue;
        }
        seen_data = true;
        let m: f64 = minute
            .parse()
            .map_err(|e| format!("line {lineno}: bad minute index {minute:?}: {e}"))?;
        if m != counts.len() as f64 {
            return Err(format!(
                "line {lineno}: minute indices must be consecutive from 0: \
                 expected {}, got {minute}",
                counts.len()
            ));
        }
        let c: f64 = count
            .parse()
            .map_err(|e| format!("line {lineno}: bad invocation count {count:?}: {e}"))?;
        if !c.is_finite() || c < 0.0 {
            return Err(format!(
                "line {lineno}: invocation count must be finite and >= 0, got {count}"
            ));
        }
        counts.push(c);
    }
    if counts.is_empty() {
        return Err("trace has no data rows".into());
    }
    Ok(counts)
}

/// Load per-minute counts from a `builtin:` fixture or an on-disk CSV.
pub fn load_minutes(path: &str) -> Result<Vec<f64>, String> {
    if let Some(name) = path.strip_prefix(BUILTIN_PREFIX) {
        let text = match name {
            "azure-2021-sample" => AZURE_2021_SAMPLE,
            other => {
                return Err(format!(
                    "unknown builtin production trace {other:?} \
                     (expected \"azure-2021-sample\")"
                ))
            }
        };
        parse_minutes_csv(text).map_err(|e| format!("{path}: {e}"))
    } else {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        parse_minutes_csv(&text).map_err(|e| format!("{path}: {e}"))
    }
}

/// Convert per-minute counts to per-minute rates (QPS). With `max_qps`
/// the series is peak-rescaled — the busiest minute maps to `max_qps`,
/// the way [`super::autoscale::synthesize`] pins the paper workloads —
/// otherwise the raw counts are used (count / 60 s).
pub fn per_minute_rates(counts: &[f64], max_qps: Option<f64>) -> Result<Vec<f64>, String> {
    assert!(!counts.is_empty());
    match max_qps {
        Some(m) => {
            let peak = counts.iter().copied().fold(f64::MIN, f64::max);
            if peak <= 0.0 {
                return Err("cannot peak-rescale an all-zero trace".into());
            }
            Ok(counts.iter().map(|c| c / peak * m).collect())
        }
        None => Ok(counts.iter().map(|c| c / 60.0).collect()),
    }
}

/// The piecewise-constant rate curve over the per-minute series: the
/// rate of minute ⌊t/60⌋ (the last minute extends to the horizon edge).
/// Shared by the materialized build and the streaming source so both
/// evaluate bit-identical rates.
pub fn rate_at(rates: &[f64], t: f64) -> f64 {
    rates[((t / 60.0) as usize).min(rates.len() - 1)]
}

/// Resolve a `production` scenario node to its per-minute rate curve:
/// load, truncate to `limit_minutes` if given, then rescale. Truncation
/// happens *before* peak rescaling, so `max_qps` pins the peak of the
/// served window, not of the untruncated file.
pub fn resolve_rates(
    path: &str,
    max_qps: Option<f64>,
    limit_minutes: Option<usize>,
) -> Result<Vec<f64>, String> {
    let mut counts = load_minutes(path)?;
    if let Some(n) = limit_minutes {
        counts.truncate(n);
    }
    per_minute_rates(&counts, max_qps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_csv_with_and_without_header() {
        let with = parse_minutes_csv("minute,invocations\n0,120\n1,60\n2,0\n").unwrap();
        let without = parse_minutes_csv("0,120\n1,60\n2,0\n").unwrap();
        assert_eq!(with, vec![120.0, 60.0, 0.0]);
        assert_eq!(with, without);
    }

    #[test]
    fn rejects_malformed_csv() {
        for (text, needle) in [
            ("", "no data rows"),
            ("minute,invocations\n", "no data rows"),
            ("0,10\n2,20\n", "consecutive"),
            ("0,10\n1\n", "expected"),
            ("0,10\n1,2,3\n", "expected"),
            ("0,10\n1,abc\n", "bad invocation count"),
            ("0,10\n1,-5\n", ">= 0"),
            ("0,10\n1,inf\n", "finite"),
        ] {
            let err = parse_minutes_csv(text).unwrap_err();
            assert!(err.contains(needle), "{text:?}: {err}");
        }
    }

    #[test]
    fn builtin_fixture_loads_and_is_plausible() {
        let counts = load_minutes("builtin:azure-2021-sample").unwrap();
        assert_eq!(counts.len(), 240);
        let peak = counts.iter().copied().fold(f64::MIN, f64::max);
        let mean = counts.iter().sum::<f64>() / counts.len() as f64;
        assert!(peak > 0.0 && mean > 0.0);
        // Production shape: real variation, but not a single spike.
        assert!(mean / peak > 0.3 && mean / peak < 0.9, "mean/peak {}", mean / peak);
        assert!(load_minutes("builtin:nope").unwrap_err().contains("unknown builtin"));
    }

    #[test]
    fn peak_rescale_pins_the_busiest_minute() {
        let rates = per_minute_rates(&[30.0, 120.0, 60.0], Some(200.0)).unwrap();
        assert_eq!(rates, vec![50.0, 200.0, 100.0]);
        let raw = per_minute_rates(&[30.0, 120.0], None).unwrap();
        assert_eq!(raw, vec![0.5, 2.0]);
        assert!(per_minute_rates(&[0.0, 0.0], Some(100.0)).is_err());
    }

    #[test]
    fn rate_curve_is_piecewise_constant_per_minute() {
        let rates = vec![10.0, 20.0, 30.0];
        assert_eq!(rate_at(&rates, 0.0), 10.0);
        assert_eq!(rate_at(&rates, 59.999), 10.0);
        assert_eq!(rate_at(&rates, 60.0), 20.0);
        assert_eq!(rate_at(&rates, 125.0), 30.0);
        // The last minute extends to any horizon overhang.
        assert_eq!(rate_at(&rates, 10_000.0), 30.0);
    }

    #[test]
    fn truncation_happens_before_peak_rescale() {
        // Global peak (minute 2) lies outside the 2-minute window, so
        // the window's own peak must map to max_qps.
        let dir = std::env::temp_dir().join("inferline-production-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("counts.csv");
        std::fs::write(&path, "0,50\n1,100\n2,400\n").unwrap();
        let rates = resolve_rates(path.to_str().unwrap(), Some(200.0), Some(2)).unwrap();
        assert_eq!(rates, vec![100.0, 200.0]);
    }
}
