"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every case asserts allclose against
``kernels.ref``. This is the core correctness signal for the compute layer:
if these pass, the HLO artifacts rust serves were lowered from a numerically
validated graph.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, conv, matmul, ref

_DIMS = [1, 2, 4, 8, 16, 32, 64, 128, 256]


def _arr(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.randn(*shape).astype(dtype))


# ---------------------------------------------------------------- matmul

@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from(_DIMS),
    k=st.sampled_from(_DIMS),
    n=st.sampled_from(_DIMS),
    act=st.sampled_from(["relu", "tanh", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    rng = np.random.RandomState(seed)
    x, w, b = _arr(rng, m, k), _arr(rng, k, n), _arr(rng, n)
    got = matmul.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    npt.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bf16_inputs(m, seed):
    """bf16 inputs accumulate in f32 and return bf16 (MXU-native path)."""
    rng = np.random.RandomState(seed)
    x = _arr(rng, m, 128).astype(jnp.bfloat16)
    w = _arr(rng, 128, 128).astype(jnp.bfloat16)
    b = _arr(rng, 128).astype(jnp.bfloat16)
    got = matmul.matmul_bias_act(x, w, b, act="none")
    want = ref.matmul_bias_act(x, w, b, act="none")
    assert got.dtype == jnp.bfloat16
    npt.assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(want, dtype=np.float32),
        rtol=5e-2, atol=5e-1,
    )


@pytest.mark.parametrize("bm,bn,bk", [(1, 128, 128), (8, 64, 256), (2, 32, 512)])
def test_matmul_block_overrides(bm, bn, bk):
    """Explicit BlockSpec overrides give identical numerics."""
    rng = np.random.RandomState(0)
    x, w, b = _arr(rng, 8, 512), _arr(rng, 512, 128), _arr(rng, 128)
    got = matmul.matmul_bias_act(x, w, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_bias_act(x, w, b)
    npt.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_matmul_rejects_bad_shapes():
    rng = np.random.RandomState(0)
    with pytest.raises(AssertionError):
        matmul.matmul_bias_act(_arr(rng, 4, 8), _arr(rng, 16, 4), _arr(rng, 4))


def test_matmul_rejects_bad_act():
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError):
        matmul.matmul_bias_act(
            _arr(rng, 4, 8), _arr(rng, 8, 4), _arr(rng, 4), act="gelu")


def test_vmem_footprint_within_budget():
    """Default tilings for every zoo-sized GEMM fit the VMEM budget."""
    for (m, k, n) in [(32, 3072, 256), (32, 256, 256), (32, 512, 512),
                      (1, 3072, 256), (32, 6272, 256)]:
        fp = matmul.vmem_footprint_bytes(m, n, k)
        assert fp <= matmul.VMEM_BUDGET_BYTES, (m, k, n, fp)


def test_mxu_utilization_monotone_in_batch():
    """Bigger batch tiles feed more MXU rows (until the 128 cap)."""
    utils = [matmul.mxu_utilization(b, 128, 256) for b in [1, 8, 32, 128]]
    assert all(a <= b for a, b in zip(utils, utils[1:]))
    assert utils[-1] == 1.0


# ------------------------------------------------------------- attention

@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    s=st.sampled_from([4, 16, 32, 64]),
    d=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, s, d, seed):
    rng = np.random.RandomState(seed)
    q, k, v = _arr(rng, b, s, d), _arr(rng, b, s, d), _arr(rng, b, s, d)
    got = attention.attention(q, k, v)
    want = ref.attention(q, k, v)
    npt.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_attention_softmax_stability():
    """Large-magnitude scores must not produce NaN/Inf (stable softmax)."""
    rng = np.random.RandomState(0)
    q = _arr(rng, 2, 16, 64) * 100.0
    out = attention.attention(q, q, q)
    assert np.isfinite(np.asarray(out)).all()


def test_attention_is_convex_combination():
    """Each output row lies within the row-wise min/max envelope of V."""
    rng = np.random.RandomState(1)
    q, k, v = (_arr(rng, 1, 8, 16) for _ in range(3))
    out = np.asarray(attention.attention(q, k, v))
    vmin = np.asarray(v).min(axis=1, keepdims=True) - 1e-5
    vmax = np.asarray(v).max(axis=1, keepdims=True) + 1e-5
    assert (out >= vmin).all() and (out <= vmax).all()


# ------------------------------------------------------------------ conv

@settings(max_examples=15, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    hw=st.sampled_from([8, 12, 16]),
    c=st.sampled_from([3, 12]),
    f=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_matches_ref(b, hw, c, f, seed):
    rng = np.random.RandomState(seed)
    x = _arr(rng, b, hw, hw, c)
    w = _arr(rng, 3, 3, c, f) * 0.1
    bias = _arr(rng, f) * 0.01
    got = conv.conv2d_bias_relu(x, w, bias)
    want = ref.conv2d_bias_relu(x, w, bias)
    npt.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_im2col_shape_and_content():
    rng = np.random.RandomState(0)
    x = _arr(rng, 2, 5, 5, 3)
    cols = conv.im2col(x, 3, 3)
    assert cols.shape == (2 * 3 * 3, 27)
    # First patch of first image == flattened top-left 3x3 window.
    want = np.asarray(x)[0, 0:3, 0:3, :].transpose(0, 1, 2).reshape(-1)
    # im2col stacks (ki,kj) then channel: [kh*kw, C] ordering.
    got = np.asarray(cols)[0].reshape(9, 3)
    want2 = np.asarray(x)[0, 0:3, 0:3, :].reshape(9, 3)
    npt.assert_allclose(got, want2)
