"""AOT pipeline checks: lowering, manifest integrity, HLO-text properties."""

from __future__ import annotations

import json
import os

import pytest

from compile import aot
from compile import model as zoo

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lower_model_emits_hlo_text():
    text = aot.lower_model(zoo.SPECS["langid"], 2)
    assert "HloModule" in text
    assert "ENTRY" in text
    # f32[2,256] parameter must appear (batch baked into the artifact).
    assert "f32[2,256]" in text


def test_lowered_output_is_tuple():
    """return_tuple=True => ROOT is a tuple (rust unwraps with to_tuple1)."""
    text = aot.lower_model(zoo.SPECS["tf_fast"], 1)
    root_lines = [l for l in text.splitlines() if "ROOT" in l]
    assert any("tuple" in l or "(f32" in l for l in root_lines), root_lines


def test_check_model_catches_shape_lies():
    bad = zoo.ModelSpec("bad", zoo.tf_fast, 1024, 99, "wrong out_dim")
    with pytest.raises(AssertionError):
        aot.check_model(bad, 2)


def test_lowering_is_deterministic():
    a = aot.lower_model(zoo.SPECS["tf_fast"], 4)
    b = aot.lower_model(zoo.SPECS["tf_fast"], 4)
    assert a == b


def test_emit_subset(tmp_path):
    rc = aot.main(["--out-dir", str(tmp_path), "--models", "langid",
                   "--batches", "1,2"])
    assert rc == 0
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert set(man["models"]) == {"langid"}
    assert set(man["models"]["langid"]["batches"]) == {"1", "2"}
    for meta in man["models"]["langid"]["batches"].values():
        f = tmp_path / meta["file"]
        assert f.exists() and f.stat().st_size == meta["bytes"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_built_manifest_is_complete():
    with open(os.path.join(ART_DIR, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == set(zoo.SPECS)
    for name, entry in man["models"].items():
        assert entry["in_dim"] == zoo.SPECS[name].in_dim
        assert entry["out_dim"] == zoo.SPECS[name].out_dim
        for b in zoo.BATCH_SIZES:
            meta = entry["batches"][str(b)]
            path = os.path.join(ART_DIR, meta["file"])
            assert os.path.exists(path), meta["file"]
