"""L2 model zoo checks: shapes, determinism, finiteness, batch invariance."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import numpy.testing as npt
import pytest

from compile import model as zoo


@pytest.mark.parametrize("name", sorted(zoo.SPECS))
@pytest.mark.parametrize("batch", [1, 2, 8])
def test_output_shape(name, batch):
    spec = zoo.SPECS[name]
    x = jnp.asarray(np.random.RandomState(0).randn(batch, spec.in_dim).astype(np.float32))
    y = spec.fn(x)
    assert y.shape == (batch, spec.out_dim)
    assert y.dtype == jnp.float32


@pytest.mark.parametrize("name", sorted(zoo.SPECS))
def test_deterministic(name):
    """Weights are baked constants: same input -> identical output."""
    spec = zoo.SPECS[name]
    x = jnp.asarray(np.random.RandomState(1).randn(2, spec.in_dim).astype(np.float32))
    npt.assert_array_equal(np.asarray(spec.fn(x)), np.asarray(spec.fn(x)))


@pytest.mark.parametrize("name", sorted(zoo.SPECS))
def test_finite_outputs(name):
    spec = zoo.SPECS[name]
    x = jnp.asarray(np.random.RandomState(2).randn(4, spec.in_dim).astype(np.float32) * 5)
    assert np.isfinite(np.asarray(spec.fn(x))).all()


@pytest.mark.parametrize("name", ["resnet_lite", "langid", "tf_fast", "tf_slow",
                                  "idmodel_lite", "nmt_lite"])
def test_batch_invariance(name):
    """Row i of a batched call equals the single-query call on row i.

    This is the property that makes per-model profiling sound: a batch is
    semantically just a stack of independent queries (paper Section 4.1).
    (Models with cross-batch normalization, like preprocess, normalize per
    image and are also invariant; conv models are covered implicitly.)
    """
    spec = zoo.SPECS[name]
    rng = np.random.RandomState(3)
    xs = jnp.asarray(rng.randn(4, spec.in_dim).astype(np.float32))
    batched = np.asarray(spec.fn(xs))
    for i in range(4):
        single = np.asarray(spec.fn(xs[i:i + 1]))[0]
        npt.assert_allclose(batched[i], single, rtol=2e-4, atol=2e-4)


def test_zoo_covers_all_pipeline_stages():
    needed = {"preprocess", "resnet_lite", "langid", "nmt_lite", "yolo_lite",
              "idmodel_lite", "alpr_lite", "tf_fast", "tf_slow"}
    assert needed <= set(zoo.SPECS)


def test_cascade_cost_ordering():
    """tf_slow must be meaningfully heavier than tf_fast (cascade premise)."""
    import jax
    fast = jax.jit(zoo.tf_fast).lower(
        jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
    slow = jax.jit(zoo.tf_slow).lower(
        jax.ShapeDtypeStruct((8, 1024), jnp.float32)).compile()
    fa = fast.cost_analysis()
    sa = slow.cost_analysis()
    if isinstance(fa, list):
        fa, sa = fa[0], sa[0]
    assert sa["flops"] > 5 * fa["flops"]
