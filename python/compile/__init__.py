"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT lowering.

Nothing in this package runs on the request path. ``make artifacts``
invokes ``python -m compile.aot`` once; the rust coordinator then serves
the emitted HLO-text artifacts through PJRT without touching Python.
"""
