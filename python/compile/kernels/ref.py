"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package has a reference implementation here written
with nothing but ``jax.numpy`` ops. The pytest suite asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-driven shape and
dtype sweeps; the AOT pipeline refuses to emit artifacts if any kernel
diverges from its oracle (see ``aot.py --check``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_act(x, w, b, act: str = "relu"):
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)) + b.astype(jnp.float32)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "tanh":
        out = jnp.tanh(out)
    elif act != "none":
        raise ValueError(act)
    return out.astype(x.dtype) if x.dtype != jnp.float32 else out


def attention(q, k, v):
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum("bsd,btd->bst", qf, kf) * scale
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bst,btd->bsd", probs, vf)
    return out.astype(q.dtype) if q.dtype != jnp.float32 else out


def conv2d_bias_relu(x, w, b):
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return jnp.maximum(out + b.astype(jnp.float32), 0.0)
