"""L1 Pallas kernel: fused single-head scaled-dot-product attention.

The hot-spot of the ``nmt_lite`` translation model (the paper's TF-NMT
analog). One program instance handles one batch element: the full
``softmax(Q K^T / sqrt(d)) V`` block is computed with Q/K/V tiles resident
in VMEM, so the S x S score matrix never round-trips to HBM -- this is the
TPU re-think of the GPU "fused attention in shared memory" pattern: VMEM
plays the role of the threadblock's shared memory and the two matmuls hit
the MXU back to back.

Sequence lengths here are small (<= 128) so a whole head fits in VMEM; a
production multi-block flash-style scan is not needed and would only add
latency at these sizes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[0].astype(jnp.float32)  # [S, D]
    k = k_ref[0].astype(jnp.float32)  # [S, D]
    v = v_ref[0].astype(jnp.float32)  # [S, D]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Numerically-stable softmax entirely in VMEM.
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(probs, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              interpret: bool = True) -> jax.Array:
    """Fused attention ``softmax(q k^T / sqrt(d)) v`` over ``[B, S, D]``."""
    b, s, d = q.shape
    assert k.shape == (b, s, d) and v.shape == (b, s, d)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(_attention_kernel, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        interpret=interpret,
    )(q, k, v)
    return out.astype(q.dtype) if q.dtype != jnp.float32 else out


def vmem_footprint_bytes(s: int, d: int, dtype_bytes: int = 4) -> int:
    """Resident VMEM per program: Q, K, V, O tiles + the S x S score matrix."""
    tiles = 4 * s * d * dtype_bytes
    scores = s * s * 4  # f32 scores + probs reuse the same buffer in spirit
    return tiles + scores
