"""L1 composite kernel: im2col convolution lowered onto the Pallas matmul.

The vision models (``yolo_lite``, ``alpr_lite``) need a conv block. On TPU
the idiomatic mapping is im2col + MXU matmul -- the systolic array has no
native sliding-window datapath, so convs are reshaped into dense GEMMs
(this is what XLA:TPU itself does for most convs). We therefore express
the patch extraction in jnp (it lowers to cheap gathers/reshapes that XLA
fuses) and run the arithmetically dominant GEMM through the L1 Pallas
matmul kernel so the hot loop still exercises the MXU-tiled code path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import matmul


def im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """Extract ``kh x kw`` valid patches: ``[B,H,W,C] -> [B*OH*OW, kh*kw*C]``."""
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(x[:, i:i + oh, j:j + ow, :])
    cols = jnp.stack(patches, axis=-2)  # [B, OH, OW, kh*kw, C]
    return cols.reshape(b * oh * ow, kh * kw * c)


def conv2d_bias_relu(x: jax.Array, w: jax.Array, b: jax.Array, *,
                     interpret: bool = True) -> jax.Array:
    """Valid conv + bias + relu via im2col and the Pallas GEMM.

    Args:
      x: ``[B, H, W, C]`` input.
      w: ``[KH, KW, C, F]`` filters.
      b: ``[F]`` bias.
    Returns ``[B, OH, OW, F]``.
    """
    bsz, h, width, c = x.shape
    kh, kw, c2, f = w.shape
    assert c == c2
    oh, ow = h - kh + 1, width - kw + 1
    cols = im2col(x, kh, kw)                      # [B*OH*OW, kh*kw*C]
    wmat = w.reshape(kh * kw * c, f)              # [kh*kw*C, F]
    out = matmul.matmul_bias_act(cols, wmat, b, act="relu", interpret=interpret)
    return out.reshape(bsz, oh, ow, f)
