"""L1 Pallas kernel: tiled matmul + bias + activation.

This is the dense compute hot-spot shared by every model in the InferLine
model zoo (classifier backbones, language models, cascade models). It is
authored TPU-style:

  * the grid tiles (M, N, K) into MXU-aligned blocks (128x128 where the
    operand shapes allow it) so each program instance streams one
    ``(bm, bk) @ (bk, bn)`` product through the MXU;
  * ``BlockSpec`` index maps express the HBM->VMEM schedule explicitly --
    the k axis is the innermost (minormost) grid dimension so partial
    products accumulate in the output block, which Pallas keeps resident
    in VMEM across the k steps;
  * accumulation is f32 regardless of input dtype (bf16 inputs hit the
    MXU's native bf16 x bf16 -> f32 path on real hardware).

On this image the kernel must run with ``interpret=True`` (the CPU PJRT
plugin cannot execute Mosaic custom-calls); correctness is checked against
the pure-jnp oracle in ``ref.py`` and the VMEM/MXU structural analysis
lives in ``vmem_footprint_bytes`` / ``mxu_utilization`` below, which
DESIGN.md Section-Perf consumes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array native tile (128 x 128). Block shapes are chosen as
# the largest divisor of the dim not exceeding these.
MXU_DIM = 128
# VMEM is ~16 MiB/core on current TPUs; keep the working set comfortably
# under half of it to allow double-buffering of input blocks.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024


def _block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps grids exact)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _apply_act(x, act: str):
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "tanh":
        return jnp.tanh(x)
    if act == "none":
        return x
    raise ValueError(f"unknown activation {act!r}")


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str, nk: int):
    """One (i, j, k) program: o[i, j] += x[i, k] @ w[k, j].

    The k grid axis is innermost, so o_ref stays in VMEM while the k
    blocks stream through. Bias + activation are fused into the final
    k step to avoid a second pass over the output block.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] += acc

    @pl.when(k == nk - 1)
    def _finish():
        o_ref[...] = _apply_act(o_ref[...] + b_ref[...].astype(jnp.float32), act)


@functools.partial(jax.jit, static_argnames=("act", "bm", "bn", "bk", "interpret"))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    act: str = "relu",
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
):
    """``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``[M, K]`` activations.
      w: ``[K, N]`` weights.
      b: ``[N]`` bias.
      act: ``"relu" | "tanh" | "none"``.
      bm/bn/bk: block-shape overrides (defaults: MXU-aligned divisors).
      interpret: must stay True on CPU-PJRT images (see module docstring).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {w.shape}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = bm or _block(m, MXU_DIM)
    bn = bn or _block(n, MXU_DIM)
    bk = bk or _block(k, MXU_DIM * 4)  # deeper k blocks amortize o writes
    grid = (m // bm, n // bn, k // bk)

    kernel = functools.partial(_matmul_kernel, act=act, nk=grid[2])
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w, b)
    return out.astype(x.dtype) if x.dtype != jnp.float32 else out


def vmem_footprint_bytes(m: int, n: int, k: int, dtype_bytes: int = 4,
                         bm: int | None = None, bn: int | None = None,
                         bk: int | None = None) -> int:
    """Resident VMEM bytes for one program instance (x, w, bias, o blocks).

    Used by the Section-Perf structural analysis: the footprint must fit the
    VMEM budget with room for double buffering of the streamed x/w blocks.
    """
    bm = bm or _block(m, MXU_DIM)
    bn = bn or _block(n, MXU_DIM)
    bk = bk or _block(k, MXU_DIM * 4)
    x_blk = bm * bk * dtype_bytes
    w_blk = bk * bn * dtype_bytes
    o_blk = bm * bn * 4  # f32 accumulator
    bias = bn * 4
    # x/w stream, so they are double-buffered; o and bias are resident.
    return 2 * (x_blk + w_blk) + o_blk + bias


def mxu_utilization(m: int, n: int, k: int,
                    bm: int | None = None, bn: int | None = None,
                    bk: int | None = None) -> float:
    """Fraction of MXU lanes busy for the chosen tiling (structural estimate).

    The 128x128 systolic array is fully fed only when the (bm, bn) tile
    covers it; partial tiles (e.g. batch-1 inference) idle (128-bm) rows.
    """
    bm = bm or _block(m, MXU_DIM)
    bn = bn or _block(n, MXU_DIM)
    return (min(bm, MXU_DIM) / MXU_DIM) * (min(bn, MXU_DIM) / MXU_DIM)
