"""L1 Pallas kernels: the compute hot-spots of the InferLine model zoo.

``matmul``    -- tiled MXU matmul + bias + activation (all dense layers)
``attention`` -- fused single-head attention (nmt_lite)
``conv``      -- im2col conv on top of the Pallas matmul (vision models)
``ref``       -- pure-jnp oracles used by pytest and ``aot.py --check``
"""

from . import attention, conv, matmul, ref  # noqa: F401
