"""L2 JAX model zoo for the four InferLine pipelines (paper Fig 2).

Each model is a pure jax function ``f(x) -> y`` over f32 arrays whose
dense/attention/conv hot loops run through the L1 Pallas kernels, so the
AOT-lowered HLO exercises the same code path end to end. Weights are
deterministic pseudo-random constants (seeded per model) baked into the
HLO at lowering time -- the rust runtime therefore feeds a single input
tensor and receives a single output tensor per model, which keeps the
serving ABI uniform across the zoo.

Zoo -> paper mapping
--------------------
preprocess    image crop/resize/normalize stage (no internal parallelism,
              hence the flat batching profile of paper Fig 3 left)
resnet_lite   ResNet152 analog: deep stack of dense residual blocks
langid        language-identification model (Social Media pipeline)
nmt_lite      TF-NMT analog: attention block + dense head
yolo_lite     object detector (Video Monitoring root)
idmodel_lite  vehicle/person identification branch
alpr_lite     license-plate extraction branch (OpenALPR analog)
tf_fast       cheap first-stage model of the TF Cascade
tf_slow       expensive second-stage model of the TF Cascade

Input convention: every model takes a flattened ``[batch, IN_DIM]`` f32
tensor and returns ``[batch, OUT_DIM]`` f32 (internal reshapes are free in
XLA). ``SPECS`` is the single source of truth consumed by ``aot.py`` and
mirrored into ``artifacts/manifest.json`` for the rust side.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import attention as attn_k
from .kernels import conv as conv_k
from .kernels import matmul as mm_k

INTERPRET = True  # CPU-PJRT image: Pallas must lower via interpret mode.


def _weights(seed: int, *shape: int, scale: float | None = None) -> jnp.ndarray:
    """Deterministic pseudo-random weights, He-scaled by fan-in."""
    rng = np.random.RandomState(seed)
    if scale is None:
        fan_in = shape[0] if len(shape) > 1 else 1
        scale = (2.0 / max(fan_in, 1)) ** 0.5
    return jnp.asarray(rng.randn(*shape).astype(np.float32) * scale)


def _dense(x, seed: int, n_out: int, act: str = "relu"):
    n_in = x.shape[-1]
    w = _weights(seed, n_in, n_out)
    b = _weights(seed + 1, n_out, scale=0.01)
    return mm_k.matmul_bias_act(x, w, b, act=act, interpret=INTERPRET)


# --------------------------------------------------------------------------
# Models
# --------------------------------------------------------------------------

def preprocess(x):
    """Crop/resize/normalize analog: pure element-wise work, no GEMMs.

    Mirrors the paper's 'preprocess' stage, which has no internal
    parallelism and gains nothing from batching on an accelerator.
    """
    img = x.reshape(x.shape[0], 32, 32, 3)
    img = img[:, 2:30, 2:30, :]                       # crop
    img = (img - jnp.mean(img, axis=(1, 2, 3), keepdims=True)) / (
        jnp.std(img, axis=(1, 2, 3), keepdims=True) + 1e-5
    )                                                  # normalize
    img = jnp.clip(img, -3.0, 3.0)
    img = jax.image.resize(img, (x.shape[0], 32, 32, 3), "bilinear")
    return img.reshape(x.shape[0], 3072)


def resnet_lite(x):
    """ResNet152 analog: dense stem + 6 residual blocks + classifier head."""
    h = _dense(x, 100, 256)
    for i in range(6):
        r = _dense(h, 110 + 10 * i, 256)
        r = _dense(r, 115 + 10 * i, 256, act="none")
        h = jnp.maximum(h + r, 0.0)
    return _dense(h, 190, 128, act="none")


def langid(x):
    """Language identification: small 2-layer classifier over text features."""
    h = _dense(x, 200, 128)
    return _dense(h, 210, 32, act="none")


def nmt_lite(x):
    """TF-NMT analog: single-head attention over a 32x128 sequence + head."""
    b = x.shape[0]
    seq = x.reshape(b, 32, 128)
    q = _dense(seq.reshape(b * 32, 128), 300, 128, act="none").reshape(b, 32, 128)
    k = _dense(seq.reshape(b * 32, 128), 310, 128, act="none").reshape(b, 32, 128)
    v = _dense(seq.reshape(b * 32, 128), 320, 128, act="none").reshape(b, 32, 128)
    ctx = attn_k.attention(q, k, v, interpret=INTERPRET)
    h = _dense(ctx.reshape(b * 32, 128), 330, 128)
    out = h.reshape(b, 32, 128).mean(axis=1)
    return _dense(out, 340, 256, act="none")


def yolo_lite(x):
    """Object detector analog: conv feature extractor + box/class head."""
    img = x.reshape(x.shape[0], 16, 16, 12)
    w = _weights(400, 3, 3, 12, 32)
    bias = _weights(401, 32, scale=0.01)
    feat = conv_k.conv2d_bias_relu(img, w, bias, interpret=INTERPRET)  # [B,14,14,32]
    flat = feat.reshape(x.shape[0], 14 * 14 * 32)
    h = _dense(flat, 410, 256)
    return _dense(h, 420, 40, act="none")  # 8 boxes x (4 + cls)


def idmodel_lite(x):
    """Vehicle/person identification branch: mid-size dense tower."""
    h = _dense(x, 500, 256)
    h = _dense(h, 510, 256)
    return _dense(h, 520, 64, act="none")


def alpr_lite(x):
    """License-plate extraction analog: conv + per-character head."""
    img = x.reshape(x.shape[0], 16, 16, 12)
    w = _weights(600, 3, 3, 12, 16)
    bias = _weights(601, 16, scale=0.01)
    feat = conv_k.conv2d_bias_relu(img, w, bias, interpret=INTERPRET)
    flat = feat.reshape(x.shape[0], 14 * 14 * 16)
    h = _dense(flat, 610, 128)
    return _dense(h, 620, 36, act="none")  # 36-way character logits


def tf_fast(x):
    """Cheap cascade stage: one dense layer + confidence head."""
    h = _dense(x, 700, 128)
    return _dense(h, 710, 16, act="none")


def tf_slow(x):
    """Expensive cascade stage: deep dense tower (invoked conditionally)."""
    h = _dense(x, 800, 512)
    for i in range(8):
        h = _dense(h, 810 + 10 * i, 512)
    return _dense(h, 890, 16, act="none")


# --------------------------------------------------------------------------
# Specs (single source of truth for aot.py / manifest.json / rust runtime)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    fn: Callable
    in_dim: int
    out_dim: int
    description: str


SPECS: dict[str, ModelSpec] = {
    s.name: s
    for s in [
        ModelSpec("preprocess", preprocess, 3072, 3072,
                  "image crop/resize/normalize (no internal parallelism)"),
        ModelSpec("resnet_lite", resnet_lite, 3072, 128,
                  "ResNet152 analog image classifier"),
        ModelSpec("langid", langid, 256, 32,
                  "language identification"),
        ModelSpec("nmt_lite", nmt_lite, 4096, 256,
                  "TF-NMT analog: Pallas fused attention + dense"),
        ModelSpec("yolo_lite", yolo_lite, 3072, 40,
                  "object detector analog (Pallas im2col conv)"),
        ModelSpec("idmodel_lite", idmodel_lite, 3072, 64,
                  "vehicle/person identification branch"),
        ModelSpec("alpr_lite", alpr_lite, 3072, 36,
                  "license plate extraction analog"),
        ModelSpec("tf_fast", tf_fast, 1024, 16,
                  "cascade fast model"),
        ModelSpec("tf_slow", tf_slow, 1024, 16,
                  "cascade slow model (conditional)"),
    ]
}

BATCH_SIZES = [1, 2, 4, 8, 16, 32]
