"""AOT lowering: every (model, batch size) -> HLO text artifact + manifest.

The interchange format is **HLO text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the HLO text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--check] [--models a,b]

``--check`` additionally validates each lowered model against a direct
jax evaluation before writing, so a broken kernel never reaches rust.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as zoo


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple for rust side)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(spec: zoo.ModelSpec, batch: int) -> str:
    fn = lambda x: (spec.fn(x),)  # noqa: E731 -- 1-tuple for to_tuple1()
    arg = jax.ShapeDtypeStruct((batch, spec.in_dim), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(arg))


def check_model(spec: zoo.ModelSpec, batch: int) -> None:
    """Evaluate the jitted model and sanity-check output shape/finiteness."""
    rng = np.random.RandomState(batch)
    x = jnp.asarray(rng.randn(batch, spec.in_dim).astype(np.float32))
    y = np.asarray(spec.fn(x))
    assert y.shape == (batch, spec.out_dim), (
        f"{spec.name} b={batch}: shape {y.shape} != ({batch},{spec.out_dim})")
    assert np.isfinite(y).all(), f"{spec.name} b={batch}: non-finite outputs"


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--out", default=None,
                   help="legacy single-file target; also triggers full emit")
    p.add_argument("--models", default=None, help="comma-separated subset")
    p.add_argument("--batches", default=None, help="comma-separated subset")
    p.add_argument("--check", action="store_true")
    args = p.parse_args(argv)

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    names = args.models.split(",") if args.models else list(zoo.SPECS)
    batches = ([int(b) for b in args.batches.split(",")]
               if args.batches else zoo.BATCH_SIZES)

    manifest = {"format": "hlo-text", "models": {}}
    for name in names:
        spec = zoo.SPECS[name]
        entry = {
            "in_dim": spec.in_dim,
            "out_dim": spec.out_dim,
            "description": spec.description,
            "batches": {},
        }
        for b in batches:
            if args.check:
                check_model(spec, b)
            text = lower_model(spec, b)
            fname = f"{name}_b{b}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            entry["batches"][str(b)] = {
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "bytes": len(text),
            }
            print(f"  {fname}: {len(text)} chars")
        manifest["models"][name] = entry

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    # Legacy sentinel consumed by the Makefile dependency rule.
    if args.out:
        with open(args.out, "w") as f:
            f.write("# see manifest.json; per-(model,batch) HLO in this dir\n")
    print(f"manifest: {len(manifest['models'])} models x {len(batches)} batches")
    return 0


if __name__ == "__main__":
    sys.exit(main())
